"""Paper Figure 3(b) + Appendix Figure 6: regret vs communication budget K.

Theorem 5.2 predicts K-Vib's regret shrinks as K^{-4/3} (linear speed-up in
budget) while the RSP baselines' bounds do not improve with K.

    PYTHONPATH=src python examples/budget_sweep.py [--out results/budget.json]

The sweep grid is (sampler x budget) — one ``repro.api.ExperimentSpec`` per
cell, differing only in the ``federation.budget`` field.
"""
import argparse
import json
import os

from repro import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--budgets", type=int, nargs="+", default=[5, 10, 20, 40])
    ap.add_argument("--samplers", nargs="+", default=["kvib", "vrb", "mabs", "avare"])
    ap.add_argument(
        "--python-loop",
        action="store_true",
        help="per-round Python dispatch instead of the compiled lax.scan loop",
    )
    ap.add_argument("--out", default="results/budget.json")
    args = ap.parse_args()

    results = {"config": vars(args), "regret_per_round": {}}
    for name in args.samplers:
        for k in args.budgets:
            spec = api.ExperimentSpec(
                task=api.TaskSpec(
                    name="logreg",
                    dataset="synthetic_classification",
                    dataset_kwargs=dict(
                        n_clients=args.clients, total=200 * args.clients,
                        power=2.0, seed=0,
                    ),
                ),
                sampler=api.SamplerSpec(
                    name=name,
                    kwargs={"horizon": args.rounds} if name in ("kvib", "vrb") else {},
                ),
                federation=api.FederationSpec(
                    rounds=args.rounds, budget=k, local_steps=1,
                    batch_size=64, local_lr=0.02,
                ),
                execution=api.ExecutionSpec(seed=0, compiled=not args.python_loop),
            )
            hist = api.run(spec)
            rpt = float(hist.regret.dynamic_regret()[-1] / args.rounds)
            results["regret_per_round"].setdefault(name, {})[str(k)] = rpt
            print(f"{name:<8} K={k:>3} regret/T = {rpt:.4f}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
