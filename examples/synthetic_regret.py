"""Paper Figure 2 + 3(c): dynamic regret, estimator variance, and training
loss for all samplers on the synthetic logistic-regression task; optional
gamma-sensitivity sweep.

    PYTHONPATH=src python examples/synthetic_regret.py [--rounds 300] \
        [--gamma-sweep] [--out results/synthetic.json]

Every (sampler, seed) cell is one ``repro.api.ExperimentSpec`` — the sweep
is spec construction, and ``repro.api.run`` executes each cell.
"""
import argparse
import json
import os

import jax
import numpy as np

from repro import api

SAMPLERS = ["uniform_rsp", "uniform_isp", "mabs", "vrb", "avare", "kvib"]


def make_spec(args, name, seed, compiled, **sampler_kw) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        task=api.TaskSpec(
            name="logreg",
            dataset="synthetic_classification",
            dataset_kwargs=dict(
                n_clients=args.clients, total=200 * args.clients,
                power=2.0, seed=seed,
            ),
        ),
        sampler=api.SamplerSpec(name=name, kwargs=sampler_kw),
        federation=api.FederationSpec(
            rounds=args.rounds, budget=args.budget, local_steps=1,
            batch_size=64, local_lr=0.02,
        ),
        execution=api.ExecutionSpec(seed=seed, compiled=compiled),
    )


def run_one(spec, ev):
    hist = api.run(spec, eval_data=ev)
    out = {
        "loss": [float(x) for x in hist.train_loss],
        "acc": [float(x) for x in hist.test_accuracy],
        "regret": [float(x) for x in hist.regret.dynamic_regret()],
        "sq_error": [float(x) for x in hist.estimator_sq_error],
        "cohort": [int(x) for x in hist.cohort_size],
        "wall_s": hist.wall_time_s,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--gamma-sweep", action="store_true")
    ap.add_argument(
        "--python-loop",
        action="store_true",
        help="per-round Python dispatch instead of the compiled lax.scan loop",
    )
    ap.add_argument("--out", default="results/synthetic.json")
    args = ap.parse_args()
    compiled = not args.python_loop

    results = {"config": vars(args), "runs": {}}
    for seed in range(args.seeds):
        ev = None
        for name in SAMPLERS:
            kw = {"horizon": args.rounds} if name in ("kvib", "vrb") else {}
            spec = make_spec(args, name, seed, compiled, **kw)
            if ev is None:
                ds = api.build(spec).dataset
                ev = ds.batch_all_clients(jax.random.PRNGKey(999), 8)
                ev = (ev[0].reshape(-1, ev[0].shape[-1]), ev[1].reshape(-1))
            r = run_one(spec, ev)
            results["runs"].setdefault(name, []).append(r)
            print(
                f"seed {seed} {name:<12} regret/T={r['regret'][-1]/args.rounds:9.4f} "
                f"err={np.mean(r['sq_error'][args.rounds//3:]):9.5f} "
                f"loss={r['loss'][-1]:.4f} acc={r['acc'][-1]:.3f} ({r['wall_s']:.0f}s)"
            )

    if args.gamma_sweep:
        for gamma in (1e-4, 1e-3, 1e-2, 1e-1, 1.0):
            spec = make_spec(args, "kvib", 0, compiled, horizon=args.rounds, gamma=gamma)
            hist = api.run(spec)
            reg = float(hist.regret.dynamic_regret()[-1])
            err = float(np.mean(hist.estimator_sq_error))
            results["runs"].setdefault("kvib_gamma", []).append(
                {"gamma": gamma, "regret": reg, "sq_error": err}
            )
            print(f"gamma={gamma:g} regret={reg:.2f} err={err:.5f}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
