"""Paper Figure 2 + 3(c): dynamic regret, estimator variance, and training
loss for all samplers on the synthetic logistic-regression task; optional
gamma-sensitivity sweep.

    PYTHONPATH=src python examples/synthetic_regret.py [--rounds 300] \
        [--gamma-sweep] [--out results/synthetic.json]
"""
import argparse
import json
import os

import jax
import numpy as np

from repro.core import make_sampler
from repro.data import synthetic_classification
from repro.fed import FedConfig, logistic_regression, run_federated

SAMPLERS = ["uniform_rsp", "uniform_isp", "mabs", "vrb", "avare", "kvib"]


def run_one(name, ds, cfg, ev, **sampler_kw):
    sampler = make_sampler(name, n=ds.n_clients, budget=cfg.budget, **sampler_kw)
    hist = run_federated(logistic_regression(), ds, sampler, cfg, eval_data=ev)
    return {
        "loss": [float(x) for x in hist.train_loss],
        "acc": [float(x) for x in hist.test_accuracy],
        "regret": [float(x) for x in hist.regret.dynamic_regret()],
        "sq_error": [float(x) for x in hist.estimator_sq_error],
        "cohort": [int(x) for x in hist.cohort_size],
        "wall_s": hist.wall_time_s,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--gamma-sweep", action="store_true")
    ap.add_argument(
        "--python-loop",
        action="store_true",
        help="per-round Python dispatch instead of the compiled lax.scan loop",
    )
    ap.add_argument("--out", default="results/synthetic.json")
    args = ap.parse_args()
    compiled = not args.python_loop

    results = {"config": vars(args), "runs": {}}
    for seed in range(args.seeds):
        ds = synthetic_classification(
            n_clients=args.clients, total=200 * args.clients, power=2.0, seed=seed
        )
        ev = ds.batch_all_clients(jax.random.PRNGKey(999), 8)
        ev = (ev[0].reshape(-1, ev[0].shape[-1]), ev[1].reshape(-1))
        cfg = FedConfig(
            rounds=args.rounds, budget=args.budget, local_steps=1,
            batch_size=64, local_lr=0.02, seed=seed, compiled=compiled,
        )
        for name in SAMPLERS:
            kw = {"horizon": args.rounds} if name in ("kvib", "vrb") else {}
            r = run_one(name, ds, cfg, ev, **kw)
            results["runs"].setdefault(name, []).append(r)
            print(
                f"seed {seed} {name:<12} regret/T={r['regret'][-1]/args.rounds:9.4f} "
                f"err={np.mean(r['sq_error'][args.rounds//3:]):9.5f} "
                f"loss={r['loss'][-1]:.4f} acc={r['acc'][-1]:.3f} ({r['wall_s']:.0f}s)"
            )

    if args.gamma_sweep:
        ds = synthetic_classification(
            n_clients=args.clients, total=200 * args.clients, power=2.0, seed=0
        )
        cfg = FedConfig(
            rounds=args.rounds, budget=args.budget, local_steps=1,
            batch_size=64, local_lr=0.02, seed=0, compiled=compiled,
        )
        for gamma in (1e-4, 1e-3, 1e-2, 1e-1, 1.0):
            r = run_one("kvib", ds, cfg, None, horizon=args.rounds, gamma=gamma)
            results["runs"].setdefault("kvib_gamma", []).append(
                {"gamma": gamma, "regret": r["regret"][-1], "sq_error": float(np.mean(r["sq_error"]))}
            )
            print(f"gamma={gamma:g} regret={r['regret'][-1]:.2f} err={np.mean(r['sq_error']):.5f}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
