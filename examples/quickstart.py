"""Quickstart: federated logistic regression with the K-Vib sampler.

    PYTHONPATH=src python examples/quickstart.py

Trains the paper's Section 6.1 synthetic task for 100 rounds with budget
K = 10% of clients, comparing K-Vib against uniform ISP sampling, and prints
the convergence + variance summary.

Each run is one declarative ``repro.api.ExperimentSpec``: swap the sampler
section for a new scenario, or ``spec.save("exp.json")`` and hand the JSON
to any other spec consumer (``repro.api.run``, ``--spec`` tooling).
"""
import argparse

import jax

from repro import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--python-loop",
        action="store_true",
        help="per-round Python dispatch instead of the compiled lax.scan loop",
    )
    args = ap.parse_args()

    def spec_for(sampler: str) -> api.ExperimentSpec:
        return api.ExperimentSpec(
            task=api.TaskSpec(
                name="logreg",
                dataset="synthetic_classification",
                dataset_kwargs=dict(
                    n_clients=args.clients, total=200 * args.clients,
                    power=2.0, seed=args.seed,
                ),
            ),
            sampler=api.SamplerSpec(
                name=sampler,
                kwargs={"horizon": args.rounds} if sampler == "kvib" else {},
            ),
            federation=api.FederationSpec(
                rounds=args.rounds, budget=args.budget, local_steps=2,
                batch_size=64, local_lr=0.02,
            ),
            execution=api.ExecutionSpec(
                seed=args.seed, compiled=not args.python_loop,
            ),
        )

    print(f"{'sampler':<14} {'loss':>8} {'acc':>7} {'est.err':>10} {'regret/T':>10} {'s':>6}")
    for name in ("uniform_isp", "kvib"):
        spec = spec_for(name)
        built = api.build(spec)
        ev = built.dataset.batch_all_clients(jax.random.PRNGKey(999), 8)
        ev = (ev[0].reshape(-1, ev[0].shape[-1]), ev[1].reshape(-1))
        hist = api.run(spec, eval_data=ev, built=built)
        s = hist.summary()
        print(
            f"{name:<14} {s['final_loss']:>8.4f} {s['final_acc']:>7.3f} "
            f"{s['mean_sq_error']:>10.5f} {s['final_dynamic_regret_per_round']:>10.4f} "
            f"{s['wall_time_s']:>6.1f}"
        )


if __name__ == "__main__":
    main()
