"""Paper Figure 5 (scaled): federated language-model training with client
samplers — the Section 6.3 experiment at CPU-simulation scale.

Clients hold heterogeneous token streams (heavy long-tail sizes, distinct
unigram styles); the model is a causal transformer LM.  With --model zoo the
driver trains a reduced smollm-360m from the architecture zoo through the
same federated stack (the end-to-end path used by launch/train.py).

    PYTHONPATH=src python examples/fed_lm.py [--out results/fed_lm.json]

Both model choices are spec-driven: the tiny LM is the built-in ``tiny_lm``
task, and the zoo-backed variant registers a custom Task factory
(``api.register_task``) so it too is just a name in the spec.
"""
import argparse
import json
import os

from repro import api
from repro.fed.tasks import Task


def zoo_lm_task(vocab: int):
    """A reduced smollm-360m from the zoo wrapped as a federated Task."""
    from repro.configs import get_config
    from repro.models import transformer

    cfg = get_config("smollm-360m").reduced(vocab=vocab, n_layers=4, d_model=192, d_ff=512)

    def init(key):
        return transformer.init_params(cfg, key)

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)

    def accuracy(params, batch):
        import jax.numpy as jnp

        logits, _ = transformer.forward(params, cfg, batch[0])
        return jnp.mean((jnp.argmax(logits, -1) == batch[1]).astype(jnp.float32))

    return Task("smollm-reduced", init, loss, accuracy)


api.register_task("smollm_reduced_lm", zoo_lm_task)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--model", choices=["tiny", "zoo"], default="tiny")
    ap.add_argument("--samplers", nargs="+", default=["uniform_isp", "vrb", "avare", "kvib"])
    ap.add_argument("--out", default="results/fed_lm.json")
    args = ap.parse_args()

    task_name = "tiny_lm" if args.model == "tiny" else "smollm_reduced_lm"
    results = {"config": vars(args), "runs": {}}
    for name in args.samplers:
        spec = api.ExperimentSpec(
            task=api.TaskSpec(
                name=task_name,
                kwargs=dict(vocab=args.vocab),
                dataset="synthetic_tokens",
                dataset_kwargs=dict(
                    n_clients=args.clients, seq_len=args.seq, vocab=args.vocab,
                    total_seqs=60 * args.clients, power=2.2, seed=0,
                ),
            ),
            sampler=api.SamplerSpec(
                name=name,
                kwargs={"horizon": args.rounds} if name in ("kvib", "vrb") else {},
            ),
            federation=api.FederationSpec(
                rounds=args.rounds, budget=args.budget, local_steps=1,
                batch_size=8, local_lr=0.3 if args.model == "tiny" else 0.1,
            ),
            execution=api.ExecutionSpec(seed=0),
        )
        hist = api.run(spec)
        results["runs"][name] = {
            "loss": [float(x) for x in hist.train_loss],
            "regret": [float(x) for x in hist.regret.dynamic_regret()],
            "sq_error": [float(x) for x in hist.estimator_sq_error],
        }
        print(
            f"{name:<12} loss {hist.train_loss[0]:.3f} -> {hist.train_loss[-1]:.3f}  "
            f"regret/T={hist.regret.dynamic_regret()[-1]/args.rounds:.4f} "
            f"({hist.wall_time_s:.0f}s)"
        )

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
