"""Paper Figure 5 (scaled): federated language-model training with client
samplers — the Section 6.3 experiment at CPU-simulation scale.

Clients hold heterogeneous token streams (heavy long-tail sizes, distinct
unigram styles); the model is a causal transformer LM.  With --model zoo the
driver fans each sampler out over reduced architecture-zoo configs — dense
(smollm), MoE (qwen3), mamba2 hybrid (zamba2), and xLSTM — through the same
federated stack (the end-to-end path used by launch/train.py); --archs
narrows the sweep.

    PYTHONPATH=src python examples/fed_lm.py [--out results/fed_lm.json]

Both model choices are spec-driven: the tiny LM is the built-in ``tiny_lm``
task, and the zoo-backed variants register a custom Task factory
(``api.register_task``) so they too are just names in the spec.
"""
import argparse
import itertools
import json
import os

from repro import api
from repro.fed.tasks import Task


# --model zoo covers one reduced config per architecture family: a dense
# transformer (smollm), a top-k routed MoE (qwen3), a mamba2/attention
# hybrid (zamba2), and an mLSTM/sLSTM stack (xlstm).  All four flow through
# transformer.init_params/loss_fn, so the federated stack sees them as
# ordinary Tasks.  zamba2's 19-block pattern is shortened so the reduced
# depth stays CPU-sized (the pattern length must divide n_layers).
ZOO_ARCHS = {
    "smollm": ("smollm-360m", dict(n_layers=4, d_model=192, d_ff=512)),
    "moe": ("qwen3-moe-235b-a22b", {}),
    "ssm": (
        "zamba2-1.2b",
        dict(
            n_layers=4,
            block_pattern=("mamba2", "mamba2", "mamba2", "shared_attn"),
        ),
    ),
    "xlstm": ("xlstm-125m", {}),
}


def zoo_lm_task(vocab: int, arch: str = "smollm"):
    """A reduced zoo architecture wrapped as a federated Task."""
    from repro.configs import get_config
    from repro.models import transformer

    name, overrides = ZOO_ARCHS[arch]
    cfg = get_config(name).reduced(vocab=vocab, **overrides)

    def init(key):
        return transformer.init_params(cfg, key)

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)

    def accuracy(params, batch):
        import jax.numpy as jnp

        logits, _ = transformer.forward(params, cfg, batch[0])
        return jnp.mean((jnp.argmax(logits, -1) == batch[1]).astype(jnp.float32))

    return Task(cfg.name, init, loss, accuracy)


api.register_task("zoo_reduced_lm", zoo_lm_task)
# Back-compat alias: older result JSONs reference the smollm-only task name.
api.register_task("smollm_reduced_lm", lambda vocab: zoo_lm_task(vocab, "smollm"))


def run_serve_demo(args) -> None:
    """The closed train-to-serve loop, one process: a compiled zoo training
    run publishes every checkpoint boundary (the ``run_segmented`` publish
    hook) from a background thread while the main thread serves traffic
    from the same directory — watcher, promotion gate, hot swaps and all.

        PYTHONPATH=src python examples/fed_lm.py --serve --rounds 6 \
            --clients 8 --budget 3

    The two sides share nothing but the checkpoint directory (and the spec
    that fingerprints it): the trainer could equally be a separate process
    (``launch.train`` + ``launch.serve --follow``)."""
    import tempfile
    import threading

    import jax

    from repro.checkpoint import CheckpointManager, config_fingerprint
    from repro.serve import (
        CheckpointWatcher,
        PromotionGate,
        ServeEngine,
        ServeSession,
        heldout_batches,
    )

    arch_name, overrides = ZOO_ARCHS[args.archs[0]]
    sampler = args.samplers[0]
    spec = api.ExperimentSpec(
        task=api.TaskSpec(
            kind="zoo",
            name=arch_name,
            reduced=True,
            kwargs=dict(vocab=args.vocab, **overrides),
            dataset="synthetic_tokens",
            dataset_kwargs=dict(
                n_clients=args.clients, seq_len=args.seq, vocab=args.vocab,
                total_seqs=60 * args.clients, power=2.2, seed=0,
            ),
        ),
        sampler=api.SamplerSpec(
            name=sampler,
            kwargs={"horizon": args.rounds} if sampler in ("kvib", "vrb") else {},
        ),
        federation=api.FederationSpec(
            rounds=args.rounds, budget=args.budget, local_steps=1,
            batch_size=8, local_lr=0.1,
        ),
        execution=api.ExecutionSpec(seed=0, compiled=True, ckpt_every=2),
        serve=api.ServeSpec(batch=2, prompt_len=16, max_tokens=48, eval_batches=2),
    )
    built = api.build(spec)
    cfg, srv = built.arch_config, spec.serve

    with tempfile.TemporaryDirectory(prefix="fed_lm_serve_") as ckpt_dir:
        manager = CheckpointManager(
            ckpt_dir, fingerprint=config_fingerprint(spec.to_dict())
        )

        def publish(state, step):
            print(f"[train] committed boundary step {step}", flush=True)

        trainer = threading.Thread(
            target=api.run,
            args=(spec,),
            kwargs=dict(ckpt_manager=manager, built=built, publish=publish),
            daemon=True,
        )

        template = api.restore_template(spec, built=built)
        engine = ServeEngine(
            cfg, template.params,
            batch=srv.batch, max_seq=srv.max_seq, page_size=srv.page_size,
            temperature=srv.temperature, seed=1,
        )
        gate = PromotionGate(
            cfg,
            heldout_batches(
                built.dataset,
                n_batches=srv.eval_batches,
                batch_size=spec.federation.batch_size,
                seed=0,
            ),
            tolerance=srv.tolerance,
        )
        watcher = CheckpointWatcher(manager, template)
        traffic = [jax.random.fold_in(jax.random.PRNGKey(0), 11)]

        def prompt_fn():
            traffic[0], sub = jax.random.split(traffic[0])
            return jax.random.randint(sub, (srv.batch, srv.prompt_len), 0, cfg.vocab)

        def on_decision(cand, promoted):
            print(
                f"[serve] step {cand.step}: "
                f"{'PROMOTE' if promoted else 'ROLLBACK'} "
                f"({gate.log.records[-1].reason})",
                flush=True,
            )

        print(f"[serve] gate bar (round-0 init) = {gate.prime(engine.params):.4f}")
        trainer.start()
        session = ServeSession(
            engine, watcher, gate,
            prompt_fn=prompt_fn,
            decode_steps_per_poll=srv.decode_steps_per_poll,
            final_step=args.rounds,
            on_decision=on_decision,
        )
        summary = session.run(timeout=600.0)
        trainer.join()
    assert engine.decode_cache_entries() == 1, "decode recompiled under swaps"
    print(gate.log.render())
    print(summary.render(), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--model", choices=["tiny", "zoo"], default="tiny")
    ap.add_argument(
        "--serve", action="store_true",
        help="run the closed train-to-serve loop instead of the sampler "
        "sweep: compiled training (first of --samplers, first of --archs) "
        "publishes checkpoint boundaries while a serving engine hot-swaps "
        "the promoted ones (use a small --rounds, e.g. 6)",
    )
    ap.add_argument(
        "--archs",
        nargs="+",
        default=list(ZOO_ARCHS),
        choices=list(ZOO_ARCHS),
        help="zoo architecture families to run (only with --model zoo)",
    )
    ap.add_argument("--samplers", nargs="+", default=["uniform_isp", "vrb", "avare", "kvib"])
    ap.add_argument("--out", default="results/fed_lm.json")
    args = ap.parse_args()

    if args.serve:
        run_serve_demo(args)
        return

    # tiny runs one model; zoo fans each sampler out over the reduced
    # architecture families (result keys become "<sampler>/<arch>").
    variants = (
        [("tiny_lm", {}, None)]
        if args.model == "tiny"
        else [("zoo_reduced_lm", {"arch": a}, a) for a in args.archs]
    )
    results = {"config": vars(args), "runs": {}}
    for name, (task_name, task_kwargs, arch) in itertools.product(
        args.samplers, variants
    ):
        run_key = name if arch is None else f"{name}/{arch}"
        spec = api.ExperimentSpec(
            task=api.TaskSpec(
                name=task_name,
                kwargs=dict(vocab=args.vocab, **task_kwargs),
                dataset="synthetic_tokens",
                dataset_kwargs=dict(
                    n_clients=args.clients, seq_len=args.seq, vocab=args.vocab,
                    total_seqs=60 * args.clients, power=2.2, seed=0,
                ),
            ),
            sampler=api.SamplerSpec(
                name=name,
                kwargs={"horizon": args.rounds} if name in ("kvib", "vrb") else {},
            ),
            federation=api.FederationSpec(
                rounds=args.rounds, budget=args.budget, local_steps=1,
                batch_size=8, local_lr=0.3 if args.model == "tiny" else 0.1,
            ),
            execution=api.ExecutionSpec(seed=0),
        )
        hist = api.run(spec)
        results["runs"][run_key] = {
            "loss": [float(x) for x in hist.train_loss],
            "regret": [float(x) for x in hist.regret.dynamic_regret()],
            "sq_error": [float(x) for x in hist.estimator_sq_error],
        }
        print(
            f"{run_key:<18} loss {hist.train_loss[0]:.3f} -> {hist.train_loss[-1]:.3f}  "
            f"regret/T={hist.regret.dynamic_regret()[-1]/args.rounds:.4f} "
            f"({hist.wall_time_s:.0f}s)"
        )

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
