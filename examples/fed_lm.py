"""Paper Figure 5 (scaled): federated language-model training with client
samplers — the Section 6.3 experiment at CPU-simulation scale.

Clients hold heterogeneous token streams (heavy long-tail sizes, distinct
unigram styles); the model is a causal transformer LM.  With --model zoo the
driver fans each sampler out over reduced architecture-zoo configs — dense
(smollm), MoE (qwen3), mamba2 hybrid (zamba2), and xLSTM — through the same
federated stack (the end-to-end path used by launch/train.py); --archs
narrows the sweep.

    PYTHONPATH=src python examples/fed_lm.py [--out results/fed_lm.json]

Both model choices are spec-driven: the tiny LM is the built-in ``tiny_lm``
task, and the zoo-backed variants register a custom Task factory
(``api.register_task``) so they too are just names in the spec.
"""
import argparse
import itertools
import json
import os

from repro import api
from repro.fed.tasks import Task


# --model zoo covers one reduced config per architecture family: a dense
# transformer (smollm), a top-k routed MoE (qwen3), a mamba2/attention
# hybrid (zamba2), and an mLSTM/sLSTM stack (xlstm).  All four flow through
# transformer.init_params/loss_fn, so the federated stack sees them as
# ordinary Tasks.  zamba2's 19-block pattern is shortened so the reduced
# depth stays CPU-sized (the pattern length must divide n_layers).
ZOO_ARCHS = {
    "smollm": ("smollm-360m", dict(n_layers=4, d_model=192, d_ff=512)),
    "moe": ("qwen3-moe-235b-a22b", {}),
    "ssm": (
        "zamba2-1.2b",
        dict(
            n_layers=4,
            block_pattern=("mamba2", "mamba2", "mamba2", "shared_attn"),
        ),
    ),
    "xlstm": ("xlstm-125m", {}),
}


def zoo_lm_task(vocab: int, arch: str = "smollm"):
    """A reduced zoo architecture wrapped as a federated Task."""
    from repro.configs import get_config
    from repro.models import transformer

    name, overrides = ZOO_ARCHS[arch]
    cfg = get_config(name).reduced(vocab=vocab, **overrides)

    def init(key):
        return transformer.init_params(cfg, key)

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)

    def accuracy(params, batch):
        import jax.numpy as jnp

        logits, _ = transformer.forward(params, cfg, batch[0])
        return jnp.mean((jnp.argmax(logits, -1) == batch[1]).astype(jnp.float32))

    return Task(cfg.name, init, loss, accuracy)


api.register_task("zoo_reduced_lm", zoo_lm_task)
# Back-compat alias: older result JSONs reference the smollm-only task name.
api.register_task("smollm_reduced_lm", lambda vocab: zoo_lm_task(vocab, "smollm"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--model", choices=["tiny", "zoo"], default="tiny")
    ap.add_argument(
        "--archs",
        nargs="+",
        default=list(ZOO_ARCHS),
        choices=list(ZOO_ARCHS),
        help="zoo architecture families to run (only with --model zoo)",
    )
    ap.add_argument("--samplers", nargs="+", default=["uniform_isp", "vrb", "avare", "kvib"])
    ap.add_argument("--out", default="results/fed_lm.json")
    args = ap.parse_args()

    # tiny runs one model; zoo fans each sampler out over the reduced
    # architecture families (result keys become "<sampler>/<arch>").
    variants = (
        [("tiny_lm", {}, None)]
        if args.model == "tiny"
        else [("zoo_reduced_lm", {"arch": a}, a) for a in args.archs]
    )
    results = {"config": vars(args), "runs": {}}
    for name, (task_name, task_kwargs, arch) in itertools.product(
        args.samplers, variants
    ):
        run_key = name if arch is None else f"{name}/{arch}"
        spec = api.ExperimentSpec(
            task=api.TaskSpec(
                name=task_name,
                kwargs=dict(vocab=args.vocab, **task_kwargs),
                dataset="synthetic_tokens",
                dataset_kwargs=dict(
                    n_clients=args.clients, seq_len=args.seq, vocab=args.vocab,
                    total_seqs=60 * args.clients, power=2.2, seed=0,
                ),
            ),
            sampler=api.SamplerSpec(
                name=name,
                kwargs={"horizon": args.rounds} if name in ("kvib", "vrb") else {},
            ),
            federation=api.FederationSpec(
                rounds=args.rounds, budget=args.budget, local_steps=1,
                batch_size=8, local_lr=0.3 if args.model == "tiny" else 0.1,
            ),
            execution=api.ExecutionSpec(seed=0),
        )
        hist = api.run(spec)
        results["runs"][run_key] = {
            "loss": [float(x) for x in hist.train_loss],
            "regret": [float(x) for x in hist.regret.dynamic_regret()],
            "sq_error": [float(x) for x in hist.estimator_sq_error],
        }
        print(
            f"{run_key:<18} loss {hist.train_loss[0]:.3f} -> {hist.train_loss[-1]:.3f}  "
            f"regret/T={hist.regret.dynamic_regret()[-1]/args.rounds:.4f} "
            f"({hist.wall_time_s:.0f}s)"
        )

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
