"""Paper Figure 5 (scaled): federated language-model training with client
samplers — the Section 6.3 experiment at CPU-simulation scale.

Clients hold heterogeneous token streams (heavy long-tail sizes, distinct
unigram styles); the model is a causal transformer LM.  With --model zoo the
driver trains a reduced smollm-360m from the architecture zoo through the
same federated stack (the end-to-end path used by launch/train.py).

    PYTHONPATH=src python examples/fed_lm.py [--out results/fed_lm.json]
"""
import argparse
import json
import os

import jax
import numpy as np

from repro.core import make_sampler
from repro.data import synthetic_tokens
from repro.fed import FedConfig, run_federated, tiny_lm
from repro.fed.tasks import Task


def zoo_lm_task(vocab: int):
    """A reduced smollm-360m from the zoo wrapped as a federated Task."""
    from repro.configs import get_config
    from repro.models import transformer

    cfg = get_config("smollm-360m").reduced(vocab=vocab, n_layers=4, d_model=192, d_ff=512)

    def init(key):
        return transformer.init_params(cfg, key)

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)

    def accuracy(params, batch):
        import jax.numpy as jnp

        logits, _ = transformer.forward(params, cfg, batch[0])
        return jnp.mean((jnp.argmax(logits, -1) == batch[1]).astype(jnp.float32))

    return Task("smollm-reduced", init, loss, accuracy)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--model", choices=["tiny", "zoo"], default="tiny")
    ap.add_argument("--samplers", nargs="+", default=["uniform_isp", "vrb", "avare", "kvib"])
    ap.add_argument("--out", default="results/fed_lm.json")
    args = ap.parse_args()

    ds = synthetic_tokens(
        n_clients=args.clients, seq_len=args.seq, vocab=args.vocab,
        total_seqs=60 * args.clients, power=2.2, seed=0,
    )
    task = tiny_lm(vocab=args.vocab) if args.model == "tiny" else zoo_lm_task(args.vocab)
    cfg = FedConfig(
        rounds=args.rounds, budget=args.budget, local_steps=1,
        batch_size=8, local_lr=0.3 if args.model == "tiny" else 0.1, seed=0,
    )
    results = {"config": vars(args), "runs": {}}
    for name in args.samplers:
        kw = {"horizon": args.rounds} if name in ("kvib", "vrb") else {}
        sampler = make_sampler(name, n=ds.n_clients, budget=args.budget, **kw)
        hist = run_federated(task, ds, sampler, cfg)
        results["runs"][name] = {
            "loss": [float(x) for x in hist.train_loss],
            "regret": [float(x) for x in hist.regret.dynamic_regret()],
            "sq_error": [float(x) for x in hist.estimator_sq_error],
        }
        print(
            f"{name:<12} loss {hist.train_loss[0]:.3f} -> {hist.train_loss[-1]:.3f}  "
            f"regret/T={hist.regret.dynamic_regret()[-1]/args.rounds:.4f} "
            f"({hist.wall_time_s:.0f}s)"
        )

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
