"""Paper Figure 4: three unbalance levels (v1/v2/v3) on an image-classifier
federated task.

The paper's FEMNIST splits are reproduced *in shape*: synthetic 28x28-style
feature vectors with Dirichlet label skew and power-law sizes tuned so the
top-10%/20%/50% of clients hold ~82%/90%/98% of the data (the paper's v1/v2/
v3 statistics); the model is an MLP stand-in for the McMahan CNN at CPU
scale.  The measured quantity — convergence speed-up of K-Vib vs baselines
under decreasing data variance — is the paper's claim under test.

    PYTHONPATH=src python examples/femnist_style.py [--out results/femnist.json]

The custom data generator registers itself into the spec-level dataset
registry (``api.register_dataset``), so each (level, sampler) cell is an
ordinary ``ExperimentSpec`` whose ``dataset="vision_like"`` — custom
scenarios ride the same declarative front door as the built-ins.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.data import FederatedDataset, power_law_sizes, size_share

# (n_clients, power-law alpha) per unbalance level; alpha tuned to the
# paper's share statistics at these client counts.
LEVELS = {
    "v1": dict(n_clients=200, alpha=2.8, share_frac=0.1),
    "v2": dict(n_clients=120, alpha=2.2, share_frac=0.2),
    "v3": dict(n_clients=60, alpha=1.2, share_frac=0.5),
}
DIM, N_CLASSES = 196, 20  # 14x14 synthetic "characters"


def make_vision_like(n_clients: int, alpha: float, seed: int) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    total = 120 * n_clients
    sizes = power_law_sizes(n_clients, total, alpha=alpha, seed=seed)
    s_max = int(sizes.max())
    # class prototypes + client-specific style shift (heterogeneity)
    protos = rng.normal(0, 1, size=(N_CLASSES, DIM))
    feats = np.zeros((n_clients, s_max, DIM), np.float32)
    labels = np.zeros((n_clients, s_max), np.int32)
    for i in range(n_clients):
        style = rng.normal(0, 0.6, size=(DIM,))
        # per-client label distribution (Dirichlet skew)
        pcls = rng.dirichlet(np.full(N_CLASSES, 0.5))
        y = rng.choice(N_CLASSES, p=pcls, size=int(sizes[i]))
        x = protos[y] + style[None] + rng.normal(0, 1.6, size=(int(sizes[i]), DIM))
        feats[i, : sizes[i]] = x
        labels[i, : sizes[i]] = y
        feats[i, sizes[i]:] = feats[i, 0]
        labels[i, sizes[i]:] = labels[i, 0]
    return FederatedDataset(jnp.asarray(feats), jnp.asarray(labels), jnp.asarray(sizes))


api.register_dataset("vision_like", make_vision_like)


def rounds_to_accuracy(acc_curve, eval_every, target):
    for i, a in enumerate(acc_curve):
        if a >= target:
            return i * eval_every
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=250)
    ap.add_argument("--samplers", nargs="+", default=["uniform_isp", "mabs", "vrb", "avare", "kvib"])
    ap.add_argument("--target-acc", type=float, default=0.60)
    ap.add_argument("--out", default="results/femnist.json")
    args = ap.parse_args()

    results = {"config": vars(args), "levels": {}}
    for level, level_cfg in LEVELS.items():
        budget = max(5, int(0.05 * level_cfg["n_clients"]))

        def spec_for(name: str) -> api.ExperimentSpec:
            return api.ExperimentSpec(
                task=api.TaskSpec(
                    name="mlp",
                    kwargs=dict(dim=DIM, n_classes=N_CLASSES, hidden=128, depth=2),
                    dataset="vision_like",
                    dataset_kwargs=dict(
                        n_clients=level_cfg["n_clients"],
                        alpha=level_cfg["alpha"], seed=0,
                    ),
                ),
                sampler=api.SamplerSpec(
                    name=name,
                    kwargs={"horizon": args.rounds} if name in ("kvib", "vrb") else {},
                ),
                federation=api.FederationSpec(
                    rounds=args.rounds, budget=budget, local_steps=3,
                    batch_size=20, local_lr=0.02, eval_every=5,
                ),
                execution=api.ExecutionSpec(seed=0),
            )

        first = api.build(spec_for(args.samplers[0]))
        ds = first.dataset
        share = size_share(np.asarray(ds.sizes), level_cfg["share_frac"])
        print(f"--- {level}: N={level_cfg['n_clients']} "
              f"top-{int(level_cfg['share_frac']*100)}% hold {share:.0%}, K={budget}")
        ev = ds.batch_all_clients(jax.random.PRNGKey(7), 8)
        ev = (ev[0].reshape(-1, DIM), ev[1].reshape(-1))
        lv = {"share": share, "budget": budget, "samplers": {}}
        for name in args.samplers:
            spec = spec_for(name)
            built = first if name == args.samplers[0] else api.build(spec)
            hist = api.run(spec, eval_data=ev, built=built)
            tta = rounds_to_accuracy(
                hist.test_accuracy, spec.federation.eval_every, args.target_acc
            )
            lv["samplers"][name] = {
                "loss": [float(x) for x in hist.train_loss],
                "acc": [float(x) for x in hist.test_accuracy],
                "sq_error": [float(x) for x in hist.estimator_sq_error],
                "regret": [float(x) for x in hist.regret.dynamic_regret()],
                "rounds_to_target": tta,
            }
            print(
                f"  {name:<12} acc={hist.test_accuracy[-1]:.3f} "
                f"loss={hist.train_loss[-1]:.4f} "
                f"err={np.mean(hist.estimator_sq_error[args.rounds//3:]):.5f} "
                f"t@{args.target_acc:.0%}={tta}"
            )
        results["levels"][level] = lv

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
