"""OSMD (Appendix E.3) and clustered K-Vib (Section 7 extension) samplers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, samplers


def test_osmd_roundtrip_and_unbiased():
    n, k, d = 20, 6, 8
    s = samplers.make_sampler("osmd", n=n, budget=k)
    st = s.init()
    g = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    lam = jnp.ones(n) / n
    fb = lam * jnp.linalg.norm(g, axis=1)
    for t in range(8):
        draw = s.sample(st, jax.random.PRNGKey(t))
        st = s.update(st, draw, fb * draw.mask)
    p = s.probabilities(st)
    assert abs(float(p.sum()) - 1.0) < 1e-5  # RSP simplex
    assert float(p.min()) >= 0.2 / n - 1e-7  # floor

    # unbiasedness
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))
    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(9), trials)

    def one(key):
        draw = s.sample(st, key)
        w = estimator.client_weights(draw, lam, s.procedure, k)
        return estimator.aggregate_stacked(g, w)

    ests = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, 0))
    se = np.asarray(jnp.std(ests, 0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - target) < 5 * se + 1e-4)


def test_osmd_adapts_toward_high_feedback():
    n, k = 24, 6
    s = samplers.make_sampler("osmd", n=n, budget=k, lr=0.8)
    st = s.init()
    fb = jnp.linspace(0.05, 1.0, n)
    for t in range(60):
        draw = s.sample(st, jax.random.PRNGKey(t))
        st = s.update(st, draw, fb * draw.mask)
    p = np.asarray(s.probabilities(st))
    assert p[-6:].mean() > 1.3 * p[:6].mean()


def test_clustered_kvib_pools_feedback():
    """Unsampled clients inherit their cluster's statistics: after feedback
    only from EVEN clients, odd clients in the same cluster must have higher
    probability than clients in a never-sampled cluster."""
    n, k = 16, 4
    # clusters: 0..7 -> cluster 0 (high feedback), 8..15 -> cluster 1 (never sampled)
    cids = tuple([0] * 8 + [1] * 8)
    s = samplers.make_sampler(
        "clustered_kvib", n=n, budget=k, cluster_ids=cids, horizon=100, gamma=1e-4
    )
    st = s.init()
    # hand-crafted draws: only clients 0, 2, 4, 6 ever report feedback
    fb = jnp.zeros(n).at[jnp.array([0, 2, 4, 6])].set(1.0)
    for t in range(25):
        draw = s.sample(st, jax.random.PRNGKey(t))
        st = s.update(st, draw, fb * draw.mask)
    p = np.asarray(s.probabilities(st))
    # odd clients of cluster 0 (no own feedback) should beat cluster-1 clients
    assert p[jnp.array([1, 3, 5, 7])].mean() > 1.2 * p[8:].mean()
    assert abs(p.sum() - k) < 1e-3 * k  # ISP budget invariant


def test_clustered_kvib_unbiased():
    n, k, d = 12, 4, 6
    cids = tuple(i % 3 for i in range(n))
    s = samplers.make_sampler("clustered_kvib", n=n, budget=k, cluster_ids=cids, gamma=0.1)
    st = s.init()
    g = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    lam = jax.random.dirichlet(jax.random.PRNGKey(2), jnp.ones(n))
    fb = lam * jnp.linalg.norm(g, axis=1)
    for t in range(4):
        draw = s.sample(st, jax.random.PRNGKey(t))
        st = s.update(st, draw, fb * draw.mask)
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))
    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(7), trials)

    def one(key):
        draw = s.sample(st, key)
        w = estimator.client_weights(draw, lam, s.procedure, k)
        return estimator.aggregate_stacked(g, w)

    ests = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, 0))
    se = np.asarray(jnp.std(ests, 0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - target) < 5 * se + 1e-4)
