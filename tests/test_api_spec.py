"""The declarative ExperimentSpec front door (repro.api).

Three contracts under test:

* Serialization: ``spec -> dict -> JSON -> spec`` is the identity, unknown
  keys are rejected naming the bad field, and ``config_fingerprint`` over
  the canonical dict is the manifest compatibility guard (equal specs agree,
  ANY field change disagrees).
* Golden bit-identity: ``api.run(spec)`` reproduces the legacy
  ``run_federated(task, dataset, sampler, cfg)`` History/params bitwise for
  ISP+RSP samplers x oracle/deployable x compiled/reference, and the zoo
  dispatch reproduces the ``build_fed_scan_segment`` construction the
  launcher uses.
* CLI shim: ``launch.train``'s flags project onto the spec the old code
  paths implied (``build_spec_from_args``), and ``--dump-spec`` emits JSON
  that loads back to the identical spec.
"""
import dataclasses
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import api
from repro.api import (
    ExecutionSpec,
    ExperimentSpec,
    FederationSpec,
    SamplerSpec,
    TaskSpec,
)
from repro.checkpoint import CheckpointManager, config_fingerprint
from repro.core import make_sampler
from repro.data import synthetic_classification
from repro.fed import FedConfig, logistic_regression, run_federated


def tiny_spec(**over) -> ExperimentSpec:
    base = dict(
        task=TaskSpec(
            name="logreg",
            dataset="synthetic_classification",
            dataset_kwargs={"n_clients": 12, "total": 600, "seed": 7},
        ),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 4}),
        federation=FederationSpec(
            rounds=4, budget=4, local_steps=1, batch_size=8, local_lr=0.05
        ),
        execution=ExecutionSpec(seed=11),
    )
    base.update(over)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# Serialization round trips
# ---------------------------------------------------------------------------


def test_spec_dict_json_roundtrip_identity():
    spec = tiny_spec()
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # a second serialize of the deserialized spec is byte-identical
    assert ExperimentSpec.from_json(spec.to_json()).to_json() == spec.to_json()


def test_spec_roundtrip_normalizes_sequences():
    """Tuples inside kwargs survive the JSON-list round trip because both
    directions normalize to tuples — including nested ones."""
    spec = tiny_spec(
        sampler=SamplerSpec(
            name="clustered_kvib",
            kwargs={"cluster_ids": (0, 0, 1, 1, 2, 2, 0, 1, 2, 0, 1, 2), "horizon": 4},
        ),
        execution=ExecutionSpec(seed=11, mesh_shape=(1, 1)),
    )
    rt = ExperimentSpec.from_json(spec.to_json())
    assert rt == spec
    assert isinstance(rt.sampler.kwargs["cluster_ids"], tuple)
    assert rt.execution.mesh_shape == (1, 1)
    # constructing straight from lists lands on the same normal form
    assert SamplerSpec(name="x", kwargs={"a": [1, [2, 3]]}) == SamplerSpec(
        name="x", kwargs={"a": (1, (2, 3))}
    )


def test_spec_file_roundtrip(tmp_path):
    spec = tiny_spec()
    path = spec.save(str(tmp_path / "exp.json"))
    assert ExperimentSpec.load(path) == spec


@pytest.mark.parametrize(
    "payload, needle",
    [
        ({"bogus_section": {}}, "bogus_section"),
        ({"task": {"bogus_field": 1}}, "bogus_field"),
        ({"sampler": {"nam": "kvib"}}, "nam"),
        ({"federation": {"round": 5}}, "round"),
        ({"execution": {"sead": 3}}, "sead"),
    ],
)
def test_from_dict_rejects_unknown_keys(payload, needle):
    with pytest.raises(ValueError, match=needle):
        ExperimentSpec.from_dict(payload)


def test_from_dict_rejects_non_mapping_section():
    with pytest.raises(ValueError, match="task"):
        ExperimentSpec.from_dict({"task": ["not", "a", "mapping"]})
    with pytest.raises(ValueError, match="mapping"):
        ExperimentSpec.from_dict("not a mapping")


def test_invalid_enum_fields_raise():
    with pytest.raises(ValueError, match="kind"):
        TaskSpec(kind="neither")
    with pytest.raises(ValueError, match="server_opt"):
        FederationSpec(server_opt="sgd9000")


def test_reduced_and_kwargs_semantics_enforced():
    # reduced applies only to zoo archs; inert-but-fingerprint-perturbing
    # fields are rejected at construction
    with pytest.raises(ValueError, match="reduced"):
        TaskSpec(kind="task", name="mlp", reduced=True)
    # zoo kwargs are reduced() overrides, meaningless on a full-size arch
    with pytest.raises(ValueError, match="reduced=True"):
        TaskSpec(kind="zoo", name="smollm-360m", kwargs={"vocab": 256})


def test_zoo_rejects_unsupported_features():
    # non-fedavg server opt: the pod-scale round is a stateless update
    spec = zoo_spec()
    bad = dataclasses.replace(
        spec,
        federation=dataclasses.replace(
            spec.federation, server_opt="fedadam", server_opt_kwargs={"lr": 1e-3}
        ),
    )
    with pytest.raises(ValueError, match="fedavg"):
        api.build(bad)
    # eval_data is a simulation-stack feature; dropping it silently would
    # hand back an empty accuracy curve
    with pytest.raises(ValueError, match="eval_data"):
        api.run(zoo_spec(), eval_data=(np.zeros((2, 4)), np.zeros((2,))))


def test_dataset_builds_are_memoized_per_kwargs():
    a = api.build(tiny_spec()).dataset
    b = api.build(tiny_spec(sampler=SamplerSpec(name="vrb", kwargs={}))).dataset
    assert a is b  # same (dataset, kwargs) cell -> one materialized dataset
    other = api.build(
        tiny_spec(
            task=TaskSpec(
                name="logreg", dataset="synthetic_classification",
                dataset_kwargs={"n_clients": 12, "total": 600, "seed": 8},
            )
        )
    ).dataset
    assert other is not a


def test_build_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown task"):
        api.build(tiny_spec(task=TaskSpec(name="nope")))
    with pytest.raises(ValueError, match="unknown dataset"):
        api.build(tiny_spec(task=TaskSpec(name="logreg", dataset="nope")))
    with pytest.raises(ValueError, match="unknown zoo arch"):
        api.build(tiny_spec(task=TaskSpec(kind="zoo", name="nope")))
    with pytest.raises(ValueError, match="unknown sampler"):
        api.build(tiny_spec(sampler=SamplerSpec(name="nope")))


# ---------------------------------------------------------------------------
# Fingerprint = manifest compatibility guard
# ---------------------------------------------------------------------------


def test_fingerprint_equal_specs_agree_any_change_disagrees():
    a, b = tiny_spec(), tiny_spec()
    assert a is not b and a == b
    assert config_fingerprint(a.to_dict()) == config_fingerprint(b.to_dict())
    # the spec object itself is accepted (duck-typed to_dict)
    assert config_fingerprint(a) == config_fingerprint(a.to_dict())

    base = config_fingerprint(a.to_dict())
    changed = [
        tiny_spec(task=TaskSpec(name="logreg", dataset="synthetic_classification",
                                dataset_kwargs={"n_clients": 13, "total": 600, "seed": 7})),
        tiny_spec(sampler=SamplerSpec(name="vrb", kwargs={"horizon": 4})),
        tiny_spec(sampler=SamplerSpec(name="kvib", kwargs={"horizon": 5})),
        tiny_spec(federation=dataclasses.replace(tiny_spec().federation, budget=5)),
        tiny_spec(federation=dataclasses.replace(tiny_spec().federation, local_lr=0.06)),
        tiny_spec(execution=ExecutionSpec(seed=12)),
        tiny_spec(execution=ExecutionSpec(seed=11, oracle_metrics=False)),
        tiny_spec(execution=ExecutionSpec(seed=11, ckpt_every=2)),
    ]
    prints = [config_fingerprint(s.to_dict()) for s in changed]
    assert base not in prints, "a field change did not change the fingerprint"
    assert len(set(prints)) == len(prints), "two different specs collided"


# ---------------------------------------------------------------------------
# Golden bit-identity: api.run(spec) == legacy run_federated(...)
# ---------------------------------------------------------------------------


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb) and len(la) > 0
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", ["kvib", "vrb"])  # ISP + RSP
@pytest.mark.parametrize("oracle", [True, False])
@pytest.mark.parametrize("compiled", [True, False])
def test_api_run_matches_legacy_run_federated(name, oracle, compiled):
    spec = tiny_spec(
        sampler=SamplerSpec(name=name, kwargs={"horizon": 4}),
        execution=ExecutionSpec(seed=11, oracle_metrics=oracle, compiled=compiled),
    )
    h_api = api.run(spec)

    # the legacy construction, by hand
    ds = synthetic_classification(n_clients=12, total=600, seed=7)
    sampler = make_sampler(name, n=ds.n_clients, budget=4, horizon=4)
    cfg = FedConfig(
        rounds=4, budget=4, local_steps=1, batch_size=8, local_lr=0.05,
        seed=11, oracle_metrics=oracle, compiled=compiled,
    )
    h_legacy = run_federated(logistic_regression(), ds, sampler, cfg)

    assert h_api.train_loss == h_legacy.train_loss
    assert h_api.cohort_size == h_legacy.cohort_size
    assert h_api.estimator_sq_error == h_legacy.estimator_sq_error
    assert h_api.cohort_dropped == h_legacy.cohort_dropped
    if oracle:
        assert h_api.regret.costs == h_legacy.regret.costs
        assert h_api.regret.opt_costs == h_legacy.regret.opt_costs
    _assert_trees_equal(h_api.final_params, h_legacy.final_params)


def test_api_run_matches_legacy_with_eval_data():
    spec = tiny_spec()
    built = api.build(spec)
    ev = built.dataset.batch_all_clients(jax.random.PRNGKey(99), 4)
    ev = (ev[0].reshape(-1, ev[0].shape[-1]), ev[1].reshape(-1))
    h_api = api.run(spec, eval_data=ev, built=built)

    ds = synthetic_classification(n_clients=12, total=600, seed=7)
    sampler = make_sampler("kvib", n=ds.n_clients, budget=4, horizon=4)
    cfg = FedConfig(rounds=4, budget=4, local_steps=1, batch_size=8,
                    local_lr=0.05, seed=11)
    h_legacy = run_federated(logistic_regression(), ds, sampler, cfg, eval_data=ev)
    assert h_api.test_accuracy == h_legacy.test_accuracy
    _assert_trees_equal(h_api.final_params, h_legacy.final_params)


def test_run_rejects_built_from_different_spec():
    built = api.build(tiny_spec())
    other = tiny_spec(sampler=SamplerSpec(name="vrb", kwargs={"horizon": 4}))
    with pytest.raises(ValueError, match="different spec"):
        api.run(other, built=built)


# ---------------------------------------------------------------------------
# Zoo dispatch: api.run(spec) == the launcher's segment construction
# ---------------------------------------------------------------------------


def zoo_spec(**exec_over) -> ExperimentSpec:
    exec_kw = dict(seed=5, compiled=True)
    exec_kw.update(exec_over)
    return ExperimentSpec(
        task=TaskSpec(
            kind="zoo",
            name="smollm-360m",
            reduced=True,
            kwargs={"n_layers": 2, "d_model": 64, "d_ff": 128, "vocab": 128},
            dataset="synthetic_tokens",
            dataset_kwargs={"n_clients": 8, "seq_len": 16, "total_seqs": 256},
        ),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 3}),
        federation=FederationSpec(
            rounds=3, budget=2, cohort=3, local_steps=2, batch_size=2,
            local_lr=0.05,
        ),
        execution=ExecutionSpec(**exec_kw),
    )


def test_api_run_zoo_matches_launcher_construction():
    from repro.data import synthetic_tokens
    from repro.fed.round import build_fed_scan_segment
    from repro.fed.state import run_segmented
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer

    spec = zoo_spec()
    h_api = api.run(spec)

    # what repro.launch.train --compiled builds, by hand
    from repro.configs import get_config
    from repro.fed.round import RoundSpec

    cfg = get_config("smollm-360m").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab=128
    )
    ds = synthetic_tokens(n_clients=8, seq_len=16, vocab=cfg.vocab,
                          total_seqs=256, seed=5)
    sampler = make_sampler("kvib", n=8, budget=2, horizon=3)
    rspec = RoundSpec(cohort=3, local_steps=2, local_lr=0.05, local_batch=2)
    key = jax.random.PRNGKey(5)
    params = transformer.init_params(cfg, key)
    segment, make_state = build_fed_scan_segment(
        cfg, rspec, sampler, ds, mesh=make_host_mesh()
    )
    state = run_segmented(
        make_state(params, sampler.init(), key, 3), 3, segment
    )

    assert h_api.train_loss == [float(x) for x in np.asarray(state.metrics["loss"])]
    assert h_api.cohort_size == [
        int(x) for x in np.asarray(state.metrics["cohort_size"])
    ]
    _assert_trees_equal(h_api.final_params, state.params)


def test_api_zoo_checkpoint_resume_and_fingerprint_guard(tmp_path):
    """A spec-fingerprinted manager resumes a preempted api.run and refuses a
    changed spec; the resumed run matches the uninterrupted one bitwise."""
    from repro.fed.state import run_segmented

    spec = zoo_spec(ckpt_every=1)
    h_full = api.run(spec)

    def manager_for(s):
        return CheckpointManager(
            str(tmp_path / "ck"), fingerprint=config_fingerprint(s.to_dict())
        )

    # "preempt" by running only the first segment: restore_template + manager
    built = api.build(spec)
    from repro.api.runner import _zoo_segment_and_state

    segment, state = _zoo_segment_and_state(built)
    manager = manager_for(spec)
    run_segmented(state, 3, segment, ckpt_every=1, manager=manager, max_segments=1)

    # a changed spec must refuse to resume from this manifest
    changed = zoo_spec(ckpt_every=1, seed=6)
    with pytest.raises(ValueError, match="fingerprint"):
        manager_for(changed).restore(api.restore_template(changed))

    # the same spec resumes and finishes identically to the full run
    h_resumed = api.run(spec, ckpt_manager=manager_for(spec))
    assert h_resumed.train_loss == h_full.train_loss
    _assert_trees_equal(h_resumed.final_params, h_full.final_params)


def test_restore_template_matches_saved_treedef(tmp_path):
    """restore_template(spec) is structurally the state a manager of this
    spec saves — for both stacks."""
    spec = tiny_spec(execution=ExecutionSpec(seed=11, ckpt_every=2))
    manager = CheckpointManager(
        str(tmp_path / "sim"), fingerprint=config_fingerprint(spec.to_dict())
    )
    api.run(spec, ckpt_manager=manager)
    restored = manager.restore(api.restore_template(spec))
    assert int(restored.round) == 4

    with pytest.raises(ValueError, match="compiled"):
        api.restore_template(
            tiny_spec(execution=ExecutionSpec(seed=11, compiled=False))
        )


# ---------------------------------------------------------------------------
# Registries are extensible (custom scenarios ride the same front door)
# ---------------------------------------------------------------------------


def test_register_custom_task_and_dataset():
    from repro.fed.tasks import logistic_regression as make_logreg

    api.register_task("test_custom_logreg", make_logreg)
    api.register_dataset(
        "test_custom_data",
        lambda n_clients, seed: synthetic_classification(
            n_clients=n_clients, total=50 * n_clients, seed=seed
        ),
    )
    assert "test_custom_logreg" in api.task_names()
    assert "test_custom_data" in api.dataset_names()
    spec = tiny_spec(
        task=TaskSpec(
            name="test_custom_logreg",
            dataset="test_custom_data",
            dataset_kwargs={"n_clients": 10, "seed": 3},
        ),
        federation=FederationSpec(rounds=2, budget=3, local_steps=1, batch_size=8),
    )
    hist = api.run(spec)
    assert len(hist.train_loss) == 2
    assert all(np.isfinite(hist.train_loss))


# ---------------------------------------------------------------------------
# CLI shim: flags -> spec projection and --dump-spec JSON
# ---------------------------------------------------------------------------


def test_cli_flags_project_onto_expected_spec():
    from repro.launch.train import build_spec_from_args, make_parser

    args = make_parser().parse_args(
        ["--arch", "smollm-360m", "--reduced", "--rounds", "8", "--clients", "32",
         "--budget", "6", "--sampler", "kvib", "--seq", "64", "--cohort", "8",
         "--local-steps", "2", "--local-batch", "2", "--local-lr", "0.05",
         "--seed", "0", "--compiled", "--ckpt-every", "2"]
    )
    spec = build_spec_from_args(args)
    assert spec == ExperimentSpec(
        task=TaskSpec(
            kind="zoo", name="smollm-360m", reduced=True,
            dataset="synthetic_tokens",
            dataset_kwargs={"n_clients": 32, "seq_len": 64},
        ),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 8}),
        federation=FederationSpec(
            rounds=8, budget=6, cohort=8, local_steps=2, batch_size=2,
            local_lr=0.05,
        ),
        execution=ExecutionSpec(seed=0, compiled=True, ckpt_every=2),
    )

    # non-adaptive samplers don't get a horizon kwarg (as the old wiring had it)
    args = make_parser().parse_args(["--sampler", "uniform_isp"])
    assert build_spec_from_args(args).sampler == SamplerSpec(
        name="uniform_isp", kwargs={}
    )


def test_cli_dump_spec_roundtrip(tmp_path):
    """--dump-spec emits JSON that --spec consumes back to the identical
    spec (the CPU CLI smoke the CI workflow also runs)."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    flags = ["--arch", "smollm-360m", "--reduced", "--rounds", "3",
             "--clients", "8", "--budget", "3", "--cohort", "4",
             "--seq", "32", "--local-batch", "2"]
    dumped = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *flags, "--dump-spec"],
        capture_output=True, text=True, timeout=300, env=env,
    )
    assert dumped.returncode == 0, dumped.stderr[-2000:]
    spec = ExperimentSpec.from_json(dumped.stdout)

    from repro.launch.train import build_spec_from_args, make_parser

    assert spec == build_spec_from_args(make_parser().parse_args(flags))

    path = tmp_path / "exp.json"
    path.write_text(dumped.stdout)
    redumped = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--spec", str(path), "--dump-spec"],
        capture_output=True, text=True, timeout=300, env=env,
    )
    assert redumped.returncode == 0, redumped.stderr[-2000:]
    assert json.loads(redumped.stdout) == json.loads(dumped.stdout)


def test_cli_resume_requires_compiled():
    from repro.launch.train import main

    with pytest.raises(SystemExit):
        main(["--resume", "--rounds", "2"])


# ---------------------------------------------------------------------------
# Export hygiene
# ---------------------------------------------------------------------------


def test_api_all_exports_resolve():
    for name in api.__all__:
        assert getattr(api, name) is not None


def test_top_level_repro_reexports_api():
    import repro

    assert repro.ExperimentSpec is ExperimentSpec
    assert repro.run is api.run
    from repro import ExperimentSpec as TopSpec  # noqa: F401

    with pytest.raises(AttributeError):
        repro.not_a_real_export
