"""Integration tests: the federated loop end-to-end on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import make_sampler
from repro.data import synthetic_classification, synthetic_tokens
from repro.fed import FedConfig, logistic_regression, run_federated, tiny_lm
from repro.optim.fedopt import FedAdam


@pytest.fixture(scope="module")
def small_ds():
    return synthetic_classification(n_clients=20, total=2000, seed=1)


def test_federated_training_reduces_loss(small_ds):
    task = logistic_regression()
    # local_steps=1 so train_loss records the loss AT the broadcast global
    # params (with R>1 it records post-local-adaptation loss, which is near
    # its floor from round 0 and is not a convergence signal).
    cfg = FedConfig(rounds=40, budget=6, local_steps=1, batch_size=32, local_lr=0.05)
    s = make_sampler("kvib", n=small_ds.n_clients, budget=cfg.budget, horizon=cfg.rounds)
    h = run_federated(task, small_ds, s, cfg)
    first = np.mean(h.train_loss[:5])
    last = np.mean(h.train_loss[-5:])
    assert last < first * 0.9, (first, last)
    assert not np.isnan(h.train_loss).any()


def test_kvib_beats_uniform_on_variance():
    """The paper's central empirical claim at simulation scale: K-Vib's
    estimator error and dynamic regret drop below uniform ISP sampling once
    client heterogeneity is large (Section 6.2: 'works better in the
    cross-device FL system with a large number of clients and data
    variance')."""
    ds = synthetic_classification(n_clients=60, total=6000, power=2.5, seed=1)
    task = logistic_regression()
    cfg = FedConfig(rounds=120, budget=6, local_steps=2, batch_size=32, local_lr=0.05, seed=3)

    def run(name):
        s = make_sampler(
            name, n=ds.n_clients, budget=cfg.budget,
            **({"horizon": cfg.rounds} if name == "kvib" else {}),
        )
        return run_federated(task, ds, s, cfg)

    h_uni = run("uniform_isp")
    h_kvib = run("kvib")
    # discard the exploration prefix (K-Vib needs ~N/K rounds of burn-in
    # before its FTRL statistics separate the heavy clients)
    tail = slice(40, None)
    assert np.mean(h_kvib.estimator_sq_error[tail]) < 0.5 * np.mean(
        h_uni.estimator_sq_error[tail]
    )
    assert h_kvib.regret.dynamic_regret()[-1] < h_uni.regret.dynamic_regret()[-1]


def test_fedadam_server_optimizer(small_ds):
    task = logistic_regression()
    cfg = FedConfig(
        rounds=20, budget=5, local_steps=1, batch_size=32, local_lr=0.05,
        server_opt=FedAdam(lr=0.01),
    )
    s = make_sampler("uniform_isp", n=small_ds.n_clients, budget=cfg.budget)
    h = run_federated(task, small_ds, s, cfg)
    assert np.isfinite(h.train_loss).all()
    assert h.train_loss[-1] < h.train_loss[0]


def test_tiny_lm_federated_round():
    ds = synthetic_tokens(n_clients=8, seq_len=16, vocab=64, total_seqs=256, seed=0)
    task = tiny_lm(vocab=64, d_model=32, n_layers=1, n_heads=2)
    cfg = FedConfig(rounds=4, budget=3, local_steps=1, batch_size=4, local_lr=0.1)
    s = make_sampler("kvib", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds)
    h = run_federated(task, ds, s, cfg)
    assert np.isfinite(h.train_loss).all()


def test_checkpoint_roundtrip(tmp_path, small_ds):
    task = logistic_regression()
    key = jax.random.PRNGKey(0)
    params = task.init(key)
    s = make_sampler("kvib", n=20, budget=5, gamma=0.1)
    st = s.init()
    draw = s.sample(st, key)
    st = s.update(st, draw, jnp.ones(20) * draw.mask)
    state = {"params": params, "sampler": st}
    f = save_checkpoint(str(tmp_path / "ckpt"), state)
    template = {"params": task.init(jax.random.PRNGKey(1)), "sampler": s.init()}
    restored = restore_checkpoint(f, template)
    np.testing.assert_allclose(
        np.asarray(restored["sampler"].stats), np.asarray(st.stats)
    )
    np.testing.assert_allclose(
        np.asarray(restored["params"]["w"]), np.asarray(params["w"])
    )


def test_checkpoint_shape_mismatch_raises(tmp_path):
    f = save_checkpoint(str(tmp_path / "c"), {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore_checkpoint(f, {"a": jnp.zeros((4,))})


def test_partition_statistics():
    from repro.data import power_law_sizes, size_share, dirichlet_label_partition

    sizes = power_law_sizes(100, 50000, alpha=2.0, seed=0)
    assert sizes.sum() == 50000
    assert size_share(sizes, 0.1) > 0.4  # heavy head
    labels = np.random.default_rng(0).integers(0, 10, size=5000)
    parts = dirichlet_label_partition(labels, 20, beta=0.2, seed=0)
    assert sum(len(p) for p in parts) == 5000
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) == 5000  # disjoint cover
