"""Validate the trip-count-aware HLO walker against closed-form programs."""
import subprocess
import sys
import textwrap

import pytest

# Every case spawns a fresh-interpreter probe (jax import + XLA compile with a
# forced device count) — minutes apiece on CPU hosts.  Opt in with `-m slow`.
pytestmark = pytest.mark.slow

# HLO parsing/compiling with forced device counts must not pollute the test
# process's jax state -> run probes in a subprocess and parse printed metrics.

_PROBE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(devices)d"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo import analyze_hlo

    %(body)s

    print("RESULT " + json.dumps(metrics))
    """
)


def _run(body: str, devices: int = 2) -> dict:
    import json

    proc = subprocess.run(
        [sys.executable, "-c", _PROBE % {"body": body, "devices": devices}],
        capture_output=True,
        text=True,
        timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(proc.stdout)


def test_scanned_matmul_flops_counted_with_trip_count():
    body = """
n, reps = 256, 7
def f(x):
    def body(c, _):
        return c @ c, ()
    out, _ = jax.lax.scan(body, x, None, length=reps)
    return out
a = jax.ShapeDtypeStruct((n, n), jnp.float32)
compiled = jax.jit(f).lower(a).compile()
res = analyze_hlo(compiled.as_text())
metrics = {"flops": res["flops"], "expected": 2.0 * reps * n**3}
"""
    m = _run(body, devices=1)
    assert abs(m["flops"] - m["expected"]) / m["expected"] < 0.05, m


def test_collectives_inside_scan_multiplied():
    body = """
mesh = jax.make_mesh((2,), ("x",))
n, reps = 128, 5
def f(x):
    def body(c, _):
        c = c @ c
        c = jax.lax.with_sharding_constraint(c, NamedSharding(mesh, P("x", None)))
        return c, ()
    out, _ = jax.lax.scan(body, x, None, length=reps)
    return out.sum()
a = jax.ShapeDtypeStruct((n, n), jnp.float32)
compiled = jax.jit(f, in_shardings=(NamedSharding(mesh, P("x", None)),)).lower(a).compile()
res = analyze_hlo(compiled.as_text())
# each iteration all-gathers the (n, n) matrix: >= reps * n*n*4 bytes
metrics = {"coll": res["collective_bytes"], "floor": reps * n * n * 4.0}
"""
    m = _run(body, devices=2)
    assert m["coll"] >= m["floor"], m


def test_nested_scan_multiplicity():
    body = """
n, outer, inner = 128, 3, 4
def f(x):
    def obody(c, _):
        def ibody(d, _):
            return d @ d, ()
        d, _ = jax.lax.scan(ibody, c, None, length=inner)
        return d, ()
    out, _ = jax.lax.scan(obody, x, None, length=outer)
    return out
a = jax.ShapeDtypeStruct((n, n), jnp.float32)
compiled = jax.jit(f).lower(a).compile()
res = analyze_hlo(compiled.as_text())
metrics = {"flops": res["flops"], "expected": 2.0 * outer * inner * n**3}
"""
    m = _run(body, devices=1)
    assert abs(m["flops"] - m["expected"]) / m["expected"] < 0.05, m


def test_bytes_reasonable_for_elementwise():
    body = """
n = 1 << 20
def f(x):
    return x * 2.0 + 1.0
a = jax.ShapeDtypeStruct((n,), jnp.float32)
compiled = jax.jit(f).lower(a).compile()
res = analyze_hlo(compiled.as_text())
# elementwise-only programs are excluded by the structural traffic model
# (assumed fused into neighbors on TPU) -> expect ~0 here
metrics = {"bytes": res["bytes"], "ref": n * 8.0}
"""
    m = _run(body, devices=1)
    assert m["bytes"] <= 0.5 * m["ref"], m
