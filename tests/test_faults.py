"""The fault-realism layer (FaultSpec): deployment realism as a spec axis.

Contracts under test:

* **Spec**: ``FaultSpec`` JSON round-trips losslessly, rejects unknown keys
  and invalid values, changes the checkpoint fingerprint, and old 4-section
  spec JSON (pre-fault) still loads.  A default-constructed (disabled)
  ``FaultSpec`` projects ``faults=None`` into both legacy configs — the
  build-time branch that keeps the unfaulted round body literally the
  pre-fault program.
* **Unbiasedness**: for EVERY registry sampler, the availability-composed
  draw + deadline survivor reweighting keeps E[d^t] == sum_i lambda_i g_i
  (Monte-Carlo against the no-fault estimator's target); the Markov
  process's conditional-q correction is unbiased given the carried chain.
* **Async determinism**: the stale-delta ring buffer applies exactly the
  hand-computed staleness-discounted deltas for a constant latency, and
  ``delay == 0`` degenerates to synchronous aggregation.
* **Execution**: a faulted run is bitwise identical across compiled vs
  reference, across segmentation boundaries, across SIGKILL/resume, and
  (with a sharded sampler axis) across S=1 sharding; deadline drops surface
  in ``History.deadline_dropped``.
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import (
    ExecutionSpec,
    ExperimentSpec,
    FaultSpec,
    FederationSpec,
    SamplerSpec,
    TaskSpec,
)
from repro.checkpoint import CheckpointManager, config_fingerprint
from repro.core import estimator, samplers, stragglers

SUBPROC_ENV = {
    "PYTHONPATH": "src",
    "PATH": "/usr/bin:/bin",
    "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
}

FAULTED = FaultSpec(
    availability="markov",
    availability_kwargs={"p_on": 0.7, "p_off": 0.2},
    deadline=1.0,
    latency="exponential",
    latency_kwargs={"scale": 0.5},
    async_buffer=3,
    staleness_discount=0.5,
)


def sim_spec(fault=FAULTED, **over) -> ExperimentSpec:
    base = dict(
        task=TaskSpec(
            name="logreg",
            kwargs={"dim": 6, "n_classes": 3},
            dataset="synthetic_classification",
            dataset_kwargs={
                "n_clients": 12, "total": 600, "dim": 6, "n_classes": 3,
                "seed": 0,
            },
        ),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 6}),
        federation=FederationSpec(
            rounds=6, budget=4, local_steps=1, batch_size=8, local_lr=0.05
        ),
        execution=ExecutionSpec(seed=3),
        fault=fault,
    )
    base.update(over)
    return ExperimentSpec(**base)


def zoo_spec(fault=FAULTED, **exec_over) -> ExperimentSpec:
    exec_kw = dict(seed=5, compiled=True)
    exec_kw.update(exec_over)
    return ExperimentSpec(
        task=TaskSpec(
            kind="zoo",
            name="smollm-360m",
            reduced=True,
            kwargs={"n_layers": 2, "d_model": 64, "d_ff": 128, "vocab": 128},
            dataset="synthetic_tokens",
            dataset_kwargs={"n_clients": 8, "seq_len": 16, "total_seqs": 256},
        ),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 4}),
        federation=FederationSpec(
            rounds=4, budget=2, cohort=3, local_steps=2, batch_size=2,
            local_lr=0.05,
        ),
        execution=ExecutionSpec(**exec_kw),
        fault=fault,
    )


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# FaultSpec serialization, validation, fingerprint
# ---------------------------------------------------------------------------


def test_fault_spec_json_roundtrip_identity():
    spec = sim_spec()
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert ExperimentSpec.from_json(spec.to_json()).to_json() == spec.to_json()
    assert spec.to_dict()["fault"]["availability"] == "markov"


def test_fault_spec_unknown_key_rejected():
    d = sim_spec(fault=FaultSpec()).to_dict()
    d["fault"]["dedaline"] = 1.0  # typo'd field
    with pytest.raises((ValueError, TypeError), match="dedaline"):
        ExperimentSpec.from_dict(d)


def test_fault_spec_back_compat_four_section_load():
    """Pre-fault spec JSON (no "fault" section) still loads, to the same
    spec as an explicitly-disabled FaultSpec."""
    d = sim_spec(fault=FaultSpec()).to_dict()
    del d["fault"]
    assert ExperimentSpec.from_dict(d) == sim_spec(fault=FaultSpec())


def test_fault_spec_changes_fingerprint():
    base = config_fingerprint(sim_spec(fault=FaultSpec()).to_dict())
    prints = {base}
    for fault in (
        FaultSpec(availability="bernoulli", availability_kwargs={"q": 0.5}),
        FaultSpec(deadline=1.0, latency_kwargs={"scale": 0.5}),
        FaultSpec(async_buffer=2),
        FAULTED,
    ):
        fp = config_fingerprint(sim_spec(fault=fault).to_dict())
        assert fp not in prints, f"fingerprint collision for {fault}"
        prints.add(fp)


@pytest.mark.parametrize(
    "bad,match",
    [
        (dict(availability="sometimes"), "availability"),
        (dict(availability="bernoulli", availability_kwargs={"q": 1.5}), "q"),
        (dict(availability="bernoulli", availability_kwargs={"q": (0.0, 0.0)}),
         "all-zero"),
        (dict(availability="markov", availability_kwargs={"p_on": 0.0}), "p_on"),
        (dict(availability="diurnal", availability_kwargs={"duty": 0.0}), "duty"),
        (dict(availability_kwargs={"q": 0.5}), "null"),
        (dict(latency="pareto"), "latency"),
        (dict(deadline=-1.0), "deadline"),
        (dict(deadline=1e-6, latency_kwargs={"scale": 1e6}), "survival"),
        (dict(async_buffer=-1), "async_buffer"),
        (dict(staleness_discount=0.0), "staleness_discount"),
        (dict(round_time=0.0), "round_time"),
    ],
)
def test_fault_spec_rejects_bad_values(bad, match):
    with pytest.raises(ValueError, match=match):
        FaultSpec(**bad)


def test_disabled_fault_spec_is_inert():
    """enabled=False projects faults=None into BOTH legacy configs — the
    build-time switch that keeps the unfaulted program the pre-fault one."""
    assert not FaultSpec().enabled
    assert not FaultSpec(round_time=2.0).enabled  # no axis on
    assert FAULTED.enabled
    spec = sim_spec(fault=FaultSpec())
    assert spec.fed_config().faults is None
    assert zoo_spec(fault=FaultSpec()).round_spec().faults is None
    assert sim_spec().fed_config().faults is FAULTED
    assert zoo_spec().round_spec().faults is FAULTED


def test_monolithic_fed_scan_rejects_faults():
    """build_fed_scan (monolithic, no carried fault state) refuses a faulted
    RoundSpec instead of silently running unfaulted."""
    import dataclasses as dc

    from repro.fed.round import RoundSpec, build_fed_scan
    from repro.configs import get_config

    cfg = get_config("smollm-360m").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab=128
    )
    from repro.data import synthetic_tokens

    ds = synthetic_tokens(n_clients=8, seq_len=16, vocab=cfg.vocab,
                          total_seqs=256, seed=0)
    s = samplers.make_sampler("kvib", n=8, budget=2, horizon=3)
    rspec = RoundSpec(cohort=3, local_steps=1, local_lr=0.05, local_batch=2,
                      faults=FAULTED)
    with pytest.raises(ValueError, match="fault"):
        build_fed_scan(cfg, rspec, s, ds)
    # unfaulted RoundSpec still builds
    build_fed_scan(cfg, dc.replace(rspec, faults=None), s, ds)


# ---------------------------------------------------------------------------
# Unbiasedness: every registry sampler, availability x deadline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", samplers.sampler_names())
def test_availability_deadline_unbiased_registry_sweep(name):
    """E[d^t] == sum_i lambda_i g_i under Bernoulli availability (composed
    q*p correction) AND deadline dropout (1/survival reweighting), for every
    registered sampler — the fault layer's core estimator contract."""
    n, k, d = 16, 5, 8
    g = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    lam = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(n))
    q = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.5, maxval=1.0)
    fault = FaultSpec(
        deadline=1.0, latency="exponential", latency_kwargs={"scale": 0.4}
    )
    surv = stragglers.deadline_survival(fault)
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))

    s = samplers.make_sampler(name, n=n, budget=k)
    st = s.init()
    fb = lam * jnp.linalg.norm(g, axis=1)
    # optimal_isp is the oracle diagnostic: by contract it stores the
    # *current full* feedback (masked feedback would water-fill unobserved
    # clients to ~zero probability)
    oracle = name == "optimal_isp"
    for t in range(3):  # burn-in so adaptive states are non-trivial
        dr = s.sample(st, jax.random.PRNGKey(10 + t))
        st = s.update(st, dr, fb if oracle else fb * dr.mask)

    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(5), trials)

    def one(key):
        k1, k2, k3 = jax.random.split(key, 3)
        dr = s.sample(st, k1)
        avail = jax.random.uniform(k2, (n,)) < q
        dr = stragglers.available_draw(dr, avail, q)
        w = estimator.client_weights(dr, lam, s.procedure, s.budget)
        lat = stragglers.latency_draw(fault, (n,), k3)
        late = jnp.logical_and(dr.mask, lat > fault.deadline)
        w = jnp.where(late, 0.0, w / jnp.float32(surv))
        return estimator.aggregate_stacked(g, w)

    ests = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, axis=0))
    se = np.asarray(jnp.std(ests, axis=0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - target) < 6.0 * se + 5e-4), name


def test_markov_availability_conditionally_unbiased():
    """Given a carried chain state, availability_step's returned q IS the
    conditional availability probability, so the composed correction is
    unbiased round by round (tower property gives the unconditional case)."""
    n, k, d = 16, 5, 8
    g = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    lam = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(n))
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))
    fault = FaultSpec(
        availability="markov", availability_kwargs={"p_on": 0.6, "p_off": 0.3}
    )
    chain = jnp.arange(n) % 2 == 0  # mixed carried on/off state

    s = samplers.make_sampler("kvib", n=n, budget=k, gamma=0.05)
    st = s.init()

    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(7), trials)

    def one(key):
        k1, k2 = jax.random.split(key)
        dr = s.sample(st, k1)
        mask, q_t, new_chain = stragglers.availability_step(
            fault, chain, jnp.int32(5), k2, n
        )
        cdr = stragglers.available_draw(dr, mask, q_t)
        w = estimator.client_weights(cdr, lam, s.procedure, s.budget)
        return estimator.aggregate_stacked(g, w), new_chain

    ests, chains = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, axis=0))
    se = np.asarray(jnp.std(ests, axis=0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - target) < 6.0 * se + 5e-4)

    # the advanced chain realizes the transition kernel: on->on w.p. 1-p_off,
    # off->on w.p. p_on
    on_rate = np.asarray(jnp.mean(chains.astype(jnp.float32), axis=0))
    was_on = np.asarray(chain)
    assert np.allclose(on_rate[was_on], 0.7, atol=0.03)
    assert np.allclose(on_rate[~was_on], 0.6, atol=0.03)


def test_markov_chain_starts_all_on():
    assert bool(jnp.all(stragglers.availability_init(FAULTED, 9)))
    assert stragglers.availability_init(
        FaultSpec(availability="bernoulli"), 9
    ) is None


def test_diurnal_schedule_is_deterministic_and_excluding():
    """Diurnal q is exactly the 0/1 mask (offline clients excluded, never
    importance-corrected) and the schedule is key-independent."""
    fault = FaultSpec(
        availability="diurnal",
        availability_kwargs={"period": 8.0, "duty": 0.5},
    )
    n = 12
    m1, q1, _ = stragglers.availability_step(
        fault, None, jnp.int32(3), jax.random.PRNGKey(0), n
    )
    m2, q2, _ = stragglers.availability_step(
        fault, None, jnp.int32(3), jax.random.PRNGKey(99), n
    )
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(m1, np.float32))
    assert 0 < int(m1.sum()) < n  # the duty cycle actually splits the fleet

    s = samplers.make_sampler("uniform_isp", n=n, budget=4)
    dr = stragglers.available_draw(s.sample(s.init(), jax.random.PRNGKey(1)), m1, q1)
    w = estimator.client_weights(dr, jnp.ones(n) / n, s.procedure, s.budget)
    assert np.all(np.asarray(w)[~np.asarray(m1)] == 0.0)
    assert np.all(np.isfinite(np.asarray(w)))


# ---------------------------------------------------------------------------
# Buffered-async ring buffer: deterministic unit semantics
# ---------------------------------------------------------------------------


def test_async_step_constant_delay_matches_hand_rolled():
    """Constant latency 1.2 with round_time 1.0 -> every delta arrives one
    round late and is applied with discount rho^1; the horizon-end flush
    drains exactly the last pending delta."""
    b, dim, rho = 3, 4, 0.5
    fault = FaultSpec(
        async_buffer=b, staleness_discount=rho, round_time=1.0,
        latency="uniform", latency_kwargs={"lo": 1.2, "hi": 1.2},
    )
    buf = stragglers.fault_state_init(fault, n=8, d_dim=dim)["buf"]
    us = [jnp.full((dim,), float(t + 1), jnp.float32) for t in range(4)]
    applied = []
    for t in range(4):
        buf, apply_vec, n_arr = stragglers.async_step(
            fault, buf, us[t], jnp.int32(t), jax.random.PRNGKey(t)
        )
        applied.append(np.asarray(apply_vec))
        assert int(n_arr) == (0 if t == 0 else 1)
    # round 0 applies nothing; round t applies rho * u_{t-1}
    np.testing.assert_array_equal(applied[0], np.zeros(dim, np.float32))
    for t in range(1, 4):
        np.testing.assert_allclose(applied[t], rho * np.asarray(us[t - 1]))
    # only u_3 is still pending; flushed at t_end=4 with discount rho^1
    assert np.asarray(buf["valid"]).sum() == 1
    flushed = np.asarray(stragglers.flush_pending(buf, 4, rho))
    np.testing.assert_allclose(flushed, rho * np.asarray(us[3]))


def test_async_zero_delay_degenerates_to_synchronous():
    """latency < round_time -> delay 0: push-then-pop the same round, apply
    the delta undiscounted (rho^0), nothing ever left pending."""
    fault = FaultSpec(
        async_buffer=3, staleness_discount=0.25, round_time=1.0,
        latency="uniform", latency_kwargs={"lo": 0.0, "hi": 0.5},
    )
    buf = stragglers.fault_state_init(fault, n=8, d_dim=5)["buf"]
    for t in range(5):
        u = jnp.arange(5, dtype=jnp.float32) * (t + 1)
        buf, apply_vec, n_arr = stragglers.async_step(
            fault, buf, u, jnp.int32(t), jax.random.PRNGKey(100 + t)
        )
        np.testing.assert_array_equal(np.asarray(apply_vec), np.asarray(u))
        assert int(n_arr) == 1
        assert not np.asarray(buf["valid"]).any()


def test_async_delay_clipped_to_buffer_never_overwrites_pending():
    """Latency far beyond B * round_time clips to delay B-1, so a slot is
    always drained before the ring reuses it — no pending delta is lost:
    total applied + flushed mass equals total dispatched mass."""
    b = 3
    fault = FaultSpec(
        async_buffer=b, staleness_discount=1.0, round_time=1.0,
        latency="uniform", latency_kwargs={"lo": 100.0, "hi": 100.0},
    )
    dim, rounds = 2, 7
    buf = stragglers.fault_state_init(fault, n=4, d_dim=dim)["buf"]
    total_applied = np.zeros(dim, np.float32)
    for t in range(rounds):
        u = jnp.full((dim,), 1.0, jnp.float32)
        buf, apply_vec, _ = stragglers.async_step(
            fault, buf, u, jnp.int32(t), jax.random.PRNGKey(t)
        )
        total_applied += np.asarray(apply_vec)
    total_applied += np.asarray(stragglers.flush_pending(buf, rounds, 1.0))
    np.testing.assert_allclose(total_applied, np.full(dim, float(rounds)))


def test_tree_vec_roundtrip():
    like = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": jnp.asarray(2.5, jnp.float32),
    }
    assert stragglers.flat_dim(like) == 7
    vec = stragglers.tree_to_vec(like)
    assert vec.shape == (7,)
    back = stragglers.vec_to_tree(vec, like)
    _assert_trees_equal(back, like)


# ---------------------------------------------------------------------------
# Execution guarantees: bitwise across compiled/reference, segmentation,
# resume, and sharding
# ---------------------------------------------------------------------------


def test_faulted_sim_compiled_matches_reference_bitwise():
    spec_c = sim_spec(execution=ExecutionSpec(seed=3, compiled=True))
    spec_r = sim_spec(execution=ExecutionSpec(seed=3, compiled=False))
    h_c = api.run(spec_c)
    h_r = api.run(spec_r)
    assert h_c.train_loss == h_r.train_loss
    assert h_c.deadline_dropped == h_r.deadline_dropped
    _assert_trees_equal(h_c.final_params, h_r.final_params)
    assert all(np.isfinite(h_c.train_loss))


def test_faulted_history_reports_deadline_drops():
    """A tight deadline (survival ~10%) must surface nonzero per-round drop
    counts while the reweighted run stays finite."""
    fault = FaultSpec(
        deadline=0.05, latency="exponential", latency_kwargs={"scale": 0.5}
    )
    h = api.run(sim_spec(fault=fault))
    assert len(h.deadline_dropped) == 6
    assert sum(h.deadline_dropped) > 0
    assert all(np.isfinite(h.train_loss))


def test_unfaulted_history_has_no_deadline_channel():
    h = api.run(sim_spec(fault=FaultSpec()))
    assert getattr(h, "deadline_dropped", []) in ([], None)


def test_faulted_zoo_segmentation_bitwise():
    """Segment boundaries are bitwise-neutral under faults: the Markov chain
    and the (B, D) async buffer live in the TrainState carry, and the async
    flush happens only once at the horizon."""
    h_mono = api.run(zoo_spec(ckpt_every=0))
    h_seg1 = api.run(zoo_spec(ckpt_every=1))
    h_seg3 = api.run(zoo_spec(ckpt_every=3))
    for h in (h_seg1, h_seg3):
        assert h.train_loss == h_mono.train_loss
        assert h.deadline_dropped == h_mono.deadline_dropped
        _assert_trees_equal(h.final_params, h_mono.final_params)
    assert all(np.isfinite(h_mono.train_loss))


def test_faulted_zoo_resume_bitwise(tmp_path):
    """A faulted run preempted after one segment resumes from checkpoint and
    finishes bit-for-bit with the uninterrupted run — all fault state
    (availability chain, stale-delta buffer) rides the checkpoint."""
    from repro.api.runner import _zoo_segment_and_state
    from repro.fed.state import run_segmented

    spec = zoo_spec(ckpt_every=1)
    h_full = api.run(spec)

    def manager():
        return CheckpointManager(
            str(tmp_path / "ck"), fingerprint=config_fingerprint(spec.to_dict())
        )

    segment, state = _zoo_segment_and_state(api.build(spec))
    run_segmented(state, 4, segment, ckpt_every=1, manager=manager(),
                  max_segments=2)

    h_resumed = api.run(spec, ckpt_manager=manager())
    assert h_resumed.train_loss == h_full.train_loss
    assert h_resumed.deadline_dropped == h_full.deadline_dropped
    _assert_trees_equal(h_resumed.final_params, h_full.final_params)


def test_faulted_sim_resume_bitwise(tmp_path):
    """Same resume guarantee on the simulation stack (deployable compiled)."""
    from repro.fed.server import build_segment_runner
    from repro.fed.state import run_segmented

    spec = sim_spec(execution=ExecutionSpec(seed=3, ckpt_every=2))
    h_full = api.run(spec)

    def manager():
        return CheckpointManager(
            str(tmp_path / "ck"), fingerprint=config_fingerprint(spec.to_dict())
        )

    built = api.build(spec)
    seg, st = build_segment_runner(
        built.task, built.dataset, built.sampler, built.fed_config
    )
    st = run_segmented(st, 6, seg, ckpt_every=2, manager=manager(),
                       max_segments=1)
    assert int(st.round) == 2

    h_resumed = api.run(spec, ckpt_manager=manager())
    assert h_resumed.train_loss == h_full.train_loss
    assert h_resumed.deadline_dropped == h_full.deadline_dropped
    _assert_trees_equal(h_resumed.final_params, h_full.final_params)


def test_faulted_sharded_s1_bitwise():
    """sampler_axis on a 1-device mesh (S=1) is bitwise identical to the
    unsharded faulted run — the availability state's shard constraints are
    layout-only."""
    fault = FaultSpec(
        availability="bernoulli", availability_kwargs={"q": 0.6},
        deadline=1.0, latency_kwargs={"scale": 0.5},
    )
    h_plain = api.run(sim_spec(fault=fault))
    h_shard = api.run(
        sim_spec(fault=fault, execution=ExecutionSpec(seed=3, sampler_axis="data"))
    )
    assert h_plain.train_loss == h_shard.train_loss
    assert h_plain.deadline_dropped == h_shard.deadline_dropped
    _assert_trees_equal(h_plain.final_params, h_shard.final_params)


@pytest.mark.slow  # fresh interpreter: forced 2-device CPU mesh
def test_faulted_two_device_sharded_within_eps_subprocess():
    """Satellite: a 2-device sampler-axis-sharded run under Bernoulli
    availability + deadline matches the unsharded faulted run within psum
    reassociation eps."""
    spec_json = sim_spec(
        fault=FaultSpec(
            availability="bernoulli", availability_kwargs={"q": 0.6},
            deadline=1.0, latency_kwargs={"scale": 0.5},
        ),
        execution=ExecutionSpec(seed=3, sampler_axis="data"),
    ).to_json()
    script = textwrap.dedent(
        f"""
        import numpy as np, jax
        from repro.api import ExperimentSpec, build, run

        assert len(jax.devices()) == 2
        spec = ExperimentSpec.from_json({spec_json!r})
        built = build(spec)
        assert built.sampler.shard.num_shards == 2
        h = run(spec, built=built)
        plain = ExperimentSpec.from_dict(
            {{**spec.to_dict(),
              "execution": {{**spec.to_dict()["execution"],
                             "sampler_axis": None}}}}
        )
        ref = run(plain)
        assert all(np.isfinite(h.train_loss))
        np.testing.assert_allclose(
            h.train_loss, ref.train_loss, rtol=1e-3, atol=1e-4
        )
        print("FAULT_SHARD_OK")
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600,
        env=dict(SUBPROC_ENV, REPRO_MESH_SHAPE="2,1"),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FAULT_SHARD_OK" in proc.stdout


# ---------------------------------------------------------------------------
# CLI + lint integration
# ---------------------------------------------------------------------------


def test_cli_faults_flag_projects_onto_spec():
    from repro.launch.train import build_spec_from_args, make_parser

    fault_json = json.dumps(
        {"availability": "markov",
         "availability_kwargs": {"p_on": 0.7, "p_off": 0.2},
         "deadline": 1.0, "latency_kwargs": {"scale": 0.5},
         "async_buffer": 3}
    )
    args = make_parser().parse_args(
        ["--sampler", "kvib", "--rounds", "4", "--compiled",
         "--faults", fault_json]
    )
    spec = build_spec_from_args(args)
    assert spec.fault == FaultSpec(
        availability="markov",
        availability_kwargs={"p_on": 0.7, "p_off": 0.2},
        deadline=1.0, latency_kwargs={"scale": 0.5}, async_buffer=3,
    )
    assert spec.fault.enabled

    assert build_spec_from_args(
        make_parser().parse_args(["--sampler", "kvib"])
    ).fault == FaultSpec()


def test_lint_faulted_cell_clean_fast():
    """The faulted round bodies trace clean through the static auditors
    (fast sweep, one adaptive sampler)."""
    from repro.analysis.lint import sweep_registry

    report = sweep_registry(samplers=["kvib"], fast=True)
    assert report.ok, report.render()
    faulted = [c for c in report.checked if "faulted" in c]
    assert faulted, report.checked
