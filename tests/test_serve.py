"""The train-to-serve subsystem (``repro.serve``): paged-decode correctness
per architecture family, the compile-once hot-swap contract, temperature
sampling fixes, the checkpoint watcher + promotion gate, the ``publish``
boundary hook, and the spec plumbing (``api.ServeSpec``).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint import CheckpointManager, config_fingerprint
from repro.configs import get_config
from repro.data import synthetic_tokens
from repro.fed.state import run_segmented
from repro.models import transformer
from repro.serve import (
    Candidate,
    CheckpointWatcher,
    PromotionGate,
    ServeEngine,
    ServeSession,
    heldout_batches,
)


# One reduced config per architecture family (the fed_lm zoo set): dense,
# top-k MoE, mamba2 hybrid, and xLSTM all flow through the same paged path.
SERVE_ARCHS = {
    "dense": ("smollm-360m", dict(n_layers=2, d_model=64, d_ff=128)),
    "moe": ("qwen3-moe-235b-a22b", {}),
    "ssm": (
        "zamba2-1.2b",
        dict(n_layers=4, block_pattern=("mamba2", "mamba2", "mamba2", "shared_attn")),
    ),
    "xlstm": ("xlstm-125m", {}),
}


def _cfg(family):
    name, overrides = SERVE_ARCHS[family]
    return get_config(name).reduced(vocab=64, **overrides)


def _tiny():
    return get_config("smollm-360m").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab=64
    )


def _engine(cfg, params=None, *, seed=0, temperature=0.0, batch=2, max_seq=32):
    params = params if params is not None else transformer.init_params(
        cfg, jax.random.PRNGKey(0)
    )
    return ServeEngine(
        cfg, params, batch=batch, max_seq=max_seq, page_size=8,
        temperature=temperature, seed=seed,
    )


# ---------------------------------------------------------------------------
# Paged decode correctness: teacher-forcing per architecture family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(SERVE_ARCHS))
def test_paged_prefill_decode_matches_forward(family):
    """Prefill + decode over the PAGED cache must agree with the full forward
    (teacher forcing): the engine's serving math is the training math."""
    cfg = _cfg(family)
    if getattr(cfg, "frontend", None):
        pytest.skip(f"{cfg.name} needs aux embeddings; not a serving arch")
    key = jax.random.PRNGKey(3)
    params = transformer.init_params(cfg, key)
    b, s, extra = 2, 12, 3
    tokens = jax.random.randint(key, (b, s + extra), 0, cfg.vocab)

    logits_full, _ = transformer.forward(params, cfg, tokens)

    logits_pre, caches = transformer.prefill(
        params, cfg, tokens[:, :s], max_seq=s + extra + 1, page_size=4
    )
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, s - 1], np.float32),
        rtol=2e-2, atol=2e-2, err_msg=f"{family}: paged prefill logits",
    )
    for i in range(extra):
        logits_dec, caches = transformer.decode_step(
            params, cfg, tokens[:, s + i : s + i + 1], caches,
            jnp.asarray(s + i, jnp.int32),
        )
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0], np.float32),
            np.asarray(logits_full[:, s + i], np.float32),
            rtol=2e-2, atol=2e-2, err_msg=f"{family}: paged decode step {i}",
        )


def test_engine_greedy_matches_teacher_forcing():
    """Greedy engine output: the first token is the argmax of the full
    forward's last-position logits (the old always-greedy-first path at
    temperature 0 was right; the fix must not have changed it)."""
    cfg = _tiny()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = _engine(cfg, params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    first = eng.start(prompts)
    logits_full, _ = transformer.forward(params, cfg, prompts)
    np.testing.assert_array_equal(
        np.asarray(first[:, 0]), np.asarray(jnp.argmax(logits_full[:, -1], -1))
    )


# ---------------------------------------------------------------------------
# Hot swap: compile-once, in-flight continuity, pinned-signature validation
# ---------------------------------------------------------------------------


def test_hot_swap_zero_recompile_and_changes_output():
    """A mid-generation swap changes subsequent tokens, keeps the in-flight
    cache/position, and adds ZERO jit cache entries for decode."""
    cfg = _tiny()
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = transformer.init_params(cfg, k1)
    variant = transformer.init_params(cfg, k2)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab)

    ref = _engine(cfg, params)
    ref.start(prompts)
    ref.step(8)

    eng = _engine(cfg, params)
    eng.start(prompts)
    eng.step(4)
    eng.swap_params(variant)
    eng.step(4)

    gen_ref = np.asarray(ref.generated())
    gen = np.asarray(eng.generated())
    # identical before the swap point, diverged after it
    np.testing.assert_array_equal(gen[:, :5], gen_ref[:, :5])
    assert not np.array_equal(gen[:, 5:], gen_ref[:, 5:])
    assert eng.swaps == 1
    assert eng.index == ref.index == 16
    assert eng.decode_cache_entries() == 1, "decode recompiled across a swap"
    assert eng.prefill_cache_entries() == 1


def test_swap_rejects_treedef_and_aval_drift():
    cfg = _tiny()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = _engine(cfg, params)

    extra = dict(params)
    extra["rogue"] = jnp.zeros((3,))
    with pytest.raises(ValueError, match="treedef"):
        eng.swap_params(extra)

    drift = jax.tree_util.tree_map(lambda x: x, params)
    drift["embed"] = np.asarray(drift["embed"], np.float16)
    with pytest.raises(ValueError, match="aval drift.*embed"):
        eng.swap_params(drift)
    assert eng.swaps == 0  # rejected candidates never count


def test_engine_rejects_frontend_archs_and_bad_prompts():
    cfg = _tiny()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = _engine(cfg, params)
    with pytest.raises(ValueError, match="prompts"):
        eng.start(jnp.zeros((3, 8), jnp.int32))  # wrong batch
    with pytest.raises(ValueError, match="decode room"):
        eng.start(jnp.zeros((2, 32), jnp.int32))  # no capacity left
    with pytest.raises(RuntimeError, match="start"):
        _engine(cfg, params).step()


def test_step_is_capacity_bounded():
    cfg = _tiny()
    eng = _engine(cfg)
    eng.start(jnp.zeros((2, 28), jnp.int32))
    assert eng.capacity == 4
    assert eng.step(100) == 4  # clipped to the paged cache's room
    assert eng.step(1) == 0
    assert eng.generated().shape == (2, 5)  # first token + 4 decode steps


# ---------------------------------------------------------------------------
# Sampling fixes: temperature respected from the FIRST token; keys split
# ---------------------------------------------------------------------------


def test_temperature_zero_is_deterministic_across_seeds():
    cfg = _tiny()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    outs = []
    for seed in (0, 1):
        eng = _engine(cfg, params, seed=seed, temperature=0.0)
        eng.start(prompts)
        eng.step(6)
        outs.append(np.asarray(eng.generated()))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_temperature_affects_first_token_and_seeds_diverge():
    """The first generated token goes through the same temperature-respecting
    sampler as every later one (the old driver always took it greedily), and
    the engine's key stream is split per call (two seeds -> two streams)."""
    cfg = _tiny()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)

    greedy = _engine(cfg, params, batch=4, temperature=0.0)
    first_greedy = np.asarray(greedy.start(prompts))

    firsts = []
    for seed in (0, 1, 2):
        eng = _engine(cfg, params, batch=4, seed=seed, temperature=5.0)
        eng.start(prompts)
        eng.step(6)
        firsts.append(np.asarray(eng.generated()))
    # at temperature 5 on a 64-way vocab, 4x7 tokens all matching greedy
    # (or another seed's stream) would mean sampling is being bypassed
    assert any(not np.array_equal(f[:, :1], first_greedy) for f in firsts)
    assert not np.array_equal(firsts[0], firsts[1])
    assert not np.array_equal(firsts[1], firsts[2])


# ---------------------------------------------------------------------------
# The compile-once audit: decode under continuous swaps is lint-checkable
# ---------------------------------------------------------------------------


def test_compile_once_probe_passes_audit_across_swaps():
    from repro.analysis.lint import audit_compile_once, audit_dtypes

    cfg = _tiny()
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = transformer.init_params(cfg, k1)
    variant = transformer.init_params(cfg, k2)
    eng = _engine(cfg, params)  # fresh: decode must not be compiled yet
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)

    probe, state = eng.compile_once_probe(prompts, [params, variant])
    findings = audit_compile_once(probe, state, 2, target="serve decode")
    assert findings == [], findings
    assert eng.swaps == 0  # the probe cycles variants itself; no engine swaps

    findings = audit_dtypes(eng.decode_jaxpr(), target="serve decode step")
    assert findings == [], findings


def test_lint_serve_cell_clean():
    from repro.analysis.lint import _lint_serve_cell

    findings, checked = _lint_serve_cell(fast=True)
    assert findings == []
    assert checked  # the cell actually audited something


# ---------------------------------------------------------------------------
# ServeSpec: spec plumbing, legacy JSONs, fingerprint sensitivity
# ---------------------------------------------------------------------------


def test_serve_spec_roundtrip_and_defaults(tmp_path):
    spec = api.ExperimentSpec(serve=api.ServeSpec(batch=4, max_tokens=32))
    p = str(tmp_path / "spec.json")
    spec.save(p)
    back = api.ExperimentSpec.load(p)
    assert back.serve == spec.serve
    assert back.serve.max_seq == back.serve.prompt_len + 32

    # legacy JSON without a "serve" section loads to defaults
    d = spec.to_dict()
    del d["serve"]
    legacy = api.ExperimentSpec.from_dict(d)
    assert legacy.serve == api.ServeSpec()


def test_serve_spec_changes_fingerprint_and_validates():
    a = api.ExperimentSpec()
    b = api.ExperimentSpec(serve=api.ServeSpec(page_size=8))
    assert config_fingerprint(a.to_dict()) != config_fingerprint(b.to_dict())
    with pytest.raises(ValueError):
        api.ServeSpec(page_size=0)
    with pytest.raises(ValueError):
        api.ServeSpec(temperature=-0.5)


# ---------------------------------------------------------------------------
# Watcher: monotone, newest-wins, restore-validated
# ---------------------------------------------------------------------------


def _ckpt_state(x):
    return {"params": {"w": jnp.full((3,), float(x))}}


def test_watcher_polls_newest_committed_step_once(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    watcher = CheckpointWatcher(mgr, _ckpt_state(0.0), extract=lambda s: s["params"])
    assert watcher.poll() is None  # nothing committed yet

    mgr.save(_ckpt_state(1.0), step=2)
    cand = watcher.poll()
    assert cand.step == 2
    np.testing.assert_array_equal(np.asarray(cand.params["w"]), np.full(3, 1.0))
    assert watcher.poll() is None  # each committed step surfaces once

    mgr.save(_ckpt_state(2.0), step=4)
    mgr.save(_ckpt_state(3.0), step=6)
    cand = watcher.poll()
    assert cand.step == 6  # newest wins; step 4 skipped, not queued
    assert watcher.seen_step == 6


def test_watcher_wait_bounded_and_fingerprint_guard(tmp_path):
    fp = config_fingerprint({"run": "A"})
    mgr = CheckpointManager(str(tmp_path / "ck"), fingerprint=fp)
    watcher = CheckpointWatcher(mgr, _ckpt_state(0.0), extract=lambda s: s["params"])
    assert watcher.wait(timeout=0.05) is None  # bounded block, no commit

    mgr.save(_ckpt_state(1.0), step=2)
    assert watcher.wait(timeout=0.05).step == 2

    # a foreign run's manager must not hand the watcher a candidate
    foreign = CheckpointManager(
        str(tmp_path / "ck"), fingerprint=config_fingerprint({"run": "B"})
    )
    mgr.save(_ckpt_state(2.0), step=4)
    bad = CheckpointWatcher(foreign, _ckpt_state(0.0))
    with pytest.raises(ValueError, match="fingerprint"):
        bad.poll()


# ---------------------------------------------------------------------------
# Gate: held-out scoring, promote/rollback bookkeeping, eval key stream
# ---------------------------------------------------------------------------


def test_gate_promote_and_rollback_bookkeeping():
    cfg = _tiny()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    ds = synthetic_tokens(
        n_clients=4, seq_len=16, vocab=cfg.vocab, total_seqs=40, seed=0
    )
    batches = heldout_batches(ds, n_batches=2, batch_size=4, seed=0)
    gate = PromotionGate(cfg, batches)

    bar = gate.prime(params)
    assert np.isfinite(bar) and gate.best_loss == bar

    # equal loss clears a tolerance-0 gate (no-worse-than promotes)
    assert gate.consider(Candidate(step=2, params=params))
    assert gate.log.records[-1].reason.startswith("loss")

    # force a rollback: nothing beats a -inf incumbent
    gate.best_loss = float("-inf")
    assert not gate.consider(Candidate(step=4, params=params))
    assert gate.best_loss == float("-inf")  # rollback keeps the incumbent bar

    assert (gate.log.promotions, gate.log.rollbacks) == (1, 1)
    assert "PROMOTE" in gate.log.render() and "ROLLBACK" in gate.log.render()


def test_heldout_batches_fixed_and_eval_keyed():
    ds = synthetic_tokens(n_clients=4, seq_len=16, vocab=64, total_seqs=40, seed=0)
    a = heldout_batches(ds, n_batches=3, batch_size=4, seed=1)
    b = heldout_batches(ds, n_batches=3, batch_size=4, seed=1)
    c = heldout_batches(ds, n_batches=3, batch_size=4, seed=2)
    for (ta, ya), (tb, yb) in zip(a, b):
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
    assert any(
        not np.array_equal(np.asarray(ta), np.asarray(tc))
        for (ta, _), (tc, _) in zip(a, c)
    )


# ---------------------------------------------------------------------------
# The publish hook: fires strictly AFTER the manifest commit
# ---------------------------------------------------------------------------


class _FakeState:
    def __init__(self, rnd):
        self.round = rnd


def test_publish_fires_after_commit_before_on_segment(tmp_path):
    events = []

    class _Mgr:
        def save(self, state, step):
            events.append(("save", step))

    run_segmented(
        _FakeState(0), 6,
        lambda s, n: _FakeState(s.round + n),
        ckpt_every=2,
        manager=_Mgr(),
        publish=lambda s, step: events.append(("publish", step)),
        on_segment=lambda s, step: events.append(("seg", step)),
    )
    assert events == [
        ("save", 2), ("publish", 2), ("seg", 2),
        ("save", 4), ("publish", 4), ("seg", 4),
        ("save", 6), ("publish", 6), ("seg", 6),
    ]


def test_publish_requires_manager():
    with pytest.raises(ValueError, match="publish.*manager"):
        run_segmented(
            _FakeState(0), 2, lambda s, n: _FakeState(s.round + n),
            publish=lambda s, step: None,
        )


def test_api_run_rejects_publish_for_task_kind():
    spec = api.ExperimentSpec()  # default kind="task"
    with pytest.raises(ValueError, match="zoo"):
        api.run(spec, publish=lambda s, step: None)


# ---------------------------------------------------------------------------
# Session: the closed loop against a real manager (no threads)
# ---------------------------------------------------------------------------


def test_session_serves_promotes_and_stops_at_final_step(tmp_path):
    cfg = _tiny()
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = transformer.init_params(cfg, k1)
    trained = transformer.init_params(cfg, k2)
    ds = synthetic_tokens(
        n_clients=4, seq_len=16, vocab=cfg.vocab, total_seqs=40, seed=0
    )

    mgr = CheckpointManager(str(tmp_path / "ck"))
    template = {"params": params}
    watcher = CheckpointWatcher(mgr, template, extract=lambda s: s["params"])
    gate = PromotionGate(
        cfg, heldout_batches(ds, n_batches=2, batch_size=4, seed=0),
        tolerance=100.0,  # any finite candidate promotes: exercise the swap
    )
    eng = _engine(cfg, params)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab)
    decisions = []

    mgr.save({"params": trained}, step=2)  # committed before the loop starts
    session = ServeSession(
        eng, watcher, gate,
        prompt_fn=lambda: prompts,
        decode_steps_per_poll=4,
        final_step=2,
        on_decision=lambda c, p: decisions.append((c.step, p)),
    )
    summary = session.run(timeout=30.0, poll_timeout=0.05)

    assert decisions == [(2, True)]
    assert summary.promotions == 1 and summary.swaps == 1
    assert summary.last_step == 2
    assert summary.tokens > 0 and summary.tokens_per_sec > 0
    assert eng.decode_cache_entries() == 1
    line = summary.render()
    assert line.startswith("serve summary: promotions=1 ")
    assert "swaps=1" in line and "last_step=2" in line


# ---------------------------------------------------------------------------
# The committed bench artifact stays regression-gateable
# ---------------------------------------------------------------------------


def test_serve_swap_bench_artifact_shape():
    """The committed ratios JSON has the exact keys check_regression gates
    on, and records the compile-once evidence the acceptance bar names."""
    with open("results/BENCH_fed_serve_swap.json") as f:
        doc = json.load(f)
    assert doc["bench"] == "fed_serve_swap"
    ratios = doc["ratios"]
    assert set(ratios) == {
        "swap_over_static_us_per_token", "paged_over_recompute_us_per_token",
    }
    assert 0 < ratios["swap_over_static_us_per_token"] <= 1.11
    entry = doc["entries"][0]
    assert entry["decode_jit_cache_entries"] == 1
    assert entry["n_swaps"] >= 2
