"""The shared padded-cohort contract (fed/cohort.py): selection determinism,
inert padding, and — the launcher bugfix — unbiasedness of the |S|/C
overflow rescaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic_classification
from repro.fed import cohort


def _mask(n, included):
    m = np.zeros(n, bool)
    m[list(included)] = True
    return jnp.asarray(m)


def test_no_overflow_keeps_all_included_with_exact_weights():
    n, c = 16, 6
    included = [1, 4, 9, 13]
    w_full = jnp.where(_mask(n, included), jnp.linspace(0.5, 2.0, n), 0.0)
    sel = cohort.select_cohort(_mask(n, included), w_full, c, jax.random.PRNGKey(0))
    valid = np.asarray(sel.valid)
    ids = np.asarray(sel.ids)
    assert int(sel.n_included) == 4 and int(sel.n_dropped) == 0
    assert valid.sum() == 4
    assert sorted(ids[valid]) == included
    # rescale is exactly 1.0: kept weights are bitwise the full-mask weights
    np.testing.assert_array_equal(
        np.asarray(sel.weights)[valid], np.asarray(w_full)[ids[valid]]
    )
    # padding slots are inert: zero weight, invalid, and point at excluded ids
    assert (np.asarray(sel.weights)[~valid] == 0.0).all()
    assert not set(ids[~valid]) & set(included)


def test_overflow_drops_to_c_and_rescales_by_inverse_acceptance():
    n, c = 16, 4
    included = list(range(8))  # |S| = 8 > C = 4
    w_full = jnp.where(_mask(n, included), jnp.linspace(0.5, 2.0, n), 0.0)
    sel = cohort.select_cohort(_mask(n, included), w_full, c, jax.random.PRNGKey(3))
    valid = np.asarray(sel.valid)
    ids = np.asarray(sel.ids)
    assert int(sel.n_included) == 8 and int(sel.n_dropped) == 4
    assert valid.all()  # buffer saturated, every slot holds a kept client
    assert set(ids) <= set(included)
    # each retained weight is w_full[i] * |S|/C (inverse acceptance prob)
    np.testing.assert_allclose(
        np.asarray(sel.weights), np.asarray(w_full)[ids] * (8 / 4), rtol=1e-6
    )


def test_overflow_rescaling_is_unbiased():
    """Satellite bugfix: E[scattered slot weight of client i] == w_full[i].
    The pre-fix launcher kept the un-rescaled weights after dropping, which
    would fail this at exactly a factor C/|S| = 0.5."""
    n, c = 16, 4
    included = list(range(8))
    mask = _mask(n, included)
    w_full = jnp.where(mask, jnp.linspace(0.5, 2.0, n), 0.0)

    def scattered_weights(key):
        sel = cohort.select_cohort(mask, w_full, c, key)
        return jnp.zeros((n,)).at[sel.ids].add(jnp.where(sel.valid, sel.weights, 0.0))

    trials = 4000
    ws = jax.vmap(scattered_weights)(jax.random.split(jax.random.PRNGKey(7), trials))
    mean = np.asarray(jnp.mean(ws, axis=0))
    se = np.asarray(jnp.std(ws, axis=0)) / np.sqrt(trials)
    np.testing.assert_array_less(np.abs(mean - np.asarray(w_full)), 5.0 * se + 1e-6)


def test_overflow_selection_is_uniform_over_included():
    """Acceptance must be uniform at C/|S| per included client, or the
    rescaled estimator would be unbiased in total but skewed per client."""
    n, c = 12, 3
    included = list(range(6))
    mask = _mask(n, included)
    w_full = mask.astype(jnp.float32)

    def kept(key):
        sel = cohort.select_cohort(mask, w_full, c, key)
        return jnp.zeros((n,)).at[sel.ids].add(sel.valid.astype(jnp.float32))

    trials = 6000
    freq = np.asarray(
        jnp.mean(jax.vmap(kept)(jax.random.split(jax.random.PRNGKey(5), trials)), axis=0)
    )
    np.testing.assert_allclose(freq[included], c / len(included), atol=0.03)
    assert (freq[6:] == 0).all()


def test_scatter_cohort_padding_is_inert():
    n, c = 10, 4
    sel = cohort.CohortSelection(
        ids=jnp.asarray([2, 7, 0, 1], jnp.int32),
        weights=jnp.asarray([1.0, 2.0, 0.0, 0.0]),
        valid=jnp.asarray([True, True, False, False]),
        n_included=jnp.asarray(2, jnp.int32),
        n_dropped=jnp.asarray(0, jnp.int32),
    )
    vals = {"a": jnp.arange(c * 3, dtype=jnp.float32).reshape(c, 3) + 1.0}
    out = cohort.scatter_cohort(vals, sel, n)["a"]
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(vals["a"][0]))
    np.testing.assert_array_equal(np.asarray(out[7]), np.asarray(vals["a"][1]))
    # padding slots (pointing at clients 0 and 1) contribute nothing
    rest = np.delete(np.asarray(out), [2, 7], axis=0)
    assert (rest == 0).all()


def test_weighted_delta_sum_matches_manual():
    deltas = {"w": jnp.arange(12, dtype=jnp.float32).reshape(4, 3)}
    w = jnp.asarray([0.5, 0.0, 2.0, 1.0])
    out = cohort.weighted_delta_sum(deltas, w)["w"]
    ref = sum(float(w[i]) * np.arange(12).reshape(4, 3)[i] for i in range(4))
    np.testing.assert_allclose(np.asarray(out), ref)


def test_host_gather_padding_buffers_are_cached_across_calls():
    """Satellite perf fix: the all-zero padding buffers are one allocation per
    (shape, dtype) for the whole process, not rebuilt every round."""
    a = cohort._zero_block((2, 5, 3), "float32")
    b = cohort._zero_block((2, 5, 3), "float32")
    assert a is b
    assert cohort._zero_block((2, 5, 3), "int32") is not a
    assert (a == 0).all()


@pytest.mark.parametrize("n_valid", [0, 2, 4])
def test_host_gather_fills_padding_with_zeros(n_valid):
    ds = synthetic_classification(n_clients=8, total=400, seed=3)
    c, r, b = 4, 2, 5
    ids = np.asarray([3, 6, 1, 0], np.int32)
    valid = np.asarray([i < n_valid for i in range(c)])
    sel = cohort.CohortSelection(
        ids=jnp.asarray(ids),
        weights=jnp.where(jnp.asarray(valid), 1.0, 0.0),
        valid=jnp.asarray(valid),
        n_included=jnp.asarray(n_valid, jnp.int32),
        n_dropped=jnp.asarray(0, jnp.int32),
    )
    k_data = jax.random.PRNGKey(11)
    feats, labs = cohort.host_gather_cohort_batches(ds, sel, k_data, r, b)
    assert feats.shape == (c, r, b) + tuple(ds.features.shape[2:])
    assert labs.shape == (c, r, b) + tuple(ds.labels.shape[2:])
    for slot in range(c):
        if not valid[slot]:
            assert (np.asarray(feats[slot]) == 0).all()
            assert (np.asarray(labs[slot]) == 0).all()
            continue
        # valid slots reproduce the direct per-client gather exactly
        keys = jax.random.split(jax.random.fold_in(k_data, int(ids[slot])), r)
        for step, kr in enumerate(keys):
            f, l = ds.client_batch(int(ids[slot]), kr, b)
            np.testing.assert_array_equal(np.asarray(feats[slot, step]), np.asarray(f))
            np.testing.assert_array_equal(np.asarray(labs[slot, step]), np.asarray(l))
