"""Unit + property tests for the water-filling solvers (Lemmas 2.2/5.1/B.8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import solver


def test_paper_example_3_2():
    """Paper Section 3, Example 3.2: a = ||g_i|| = [1,3,6], K=2 -> [.25,.75,1]."""
    p = solver.isp_probabilities(jnp.array([1.0, 3.0, 6.0]), 2.0)
    np.testing.assert_allclose(np.asarray(p), [0.25, 0.75, 1.0], atol=1e-6)


def test_k1_reduces_to_rsp():
    """Section 3: with K=1 the ISP solution equals the RSP solution."""
    a = jnp.array([1.0, 3.0, 6.0])
    np.testing.assert_allclose(
        np.asarray(solver.isp_probabilities(a, 1.0)),
        np.asarray(solver.rsp_probabilities(a, 1.0)),
        atol=1e-6,
    )


def test_full_budget_saturates():
    p = solver.isp_probabilities(jnp.array([0.5, 1.0, 2.0, 9.0]), 4.0)
    np.testing.assert_allclose(np.asarray(p), np.ones(4), atol=1e-6)


def test_uniform_scores_give_uniform_probs():
    p = solver.isp_probabilities(jnp.ones(10), 3.0)
    np.testing.assert_allclose(np.asarray(p), np.full(10, 0.3), atol=1e-6)


def test_floor_is_respected():
    a = jnp.array([1e-4, 1.0, 2.0, 3.0])
    p = solver.isp_probabilities(a, 2.0, p_min=0.1)
    assert float(p.min()) >= 0.1 - 1e-7
    assert abs(float(p.sum()) - 2.0) < 1e-5


def test_mixing_strategy():
    """eq. 12: floor theta*K/N, budget preserved."""
    p = jnp.array([0.0, 0.5, 1.0, 0.5])  # sums to 2
    mixed = solver.mix_probabilities(p, 0.4, 2.0)
    assert abs(float(mixed.sum()) - 2.0) < 1e-6
    assert float(mixed.min()) >= 0.4 * 2.0 / 4 - 1e-7


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 300),
    frac=st.floats(0.01, 1.0),
    scale=st.floats(0.01, 100.0),
)
def test_isp_constraints_property(seed, n, frac, scale):
    """sum(p) == K, p in (0, 1], for arbitrary positive scores."""
    k = max(1.0, frac * n)
    a = (
        jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=1e-6, maxval=1.0)
        ** 2
        * scale
    )
    p = solver.isp_probabilities(a, k)
    assert abs(float(jnp.sum(p)) - k) < max(1e-3, 1e-4 * k)
    assert float(jnp.max(p)) <= 1.0 + 1e-6
    assert float(jnp.min(p)) > 0.0


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 100), k=st.integers(1, 50))
def test_isp_kkt_property(seed, n, k):
    """KKT: on the interior, a_i/p_i is constant; capped clients have larger
    a_i than the implied water level."""
    k = min(k, n - 1)
    a = jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=0.01, maxval=1.0)
    p = np.asarray(solver.isp_probabilities(a, float(k)))
    a = np.asarray(a)
    interior = (p < 1.0 - 1e-6) & (p > 1e-9)
    if interior.sum() >= 2:
        levels = a[interior] / p[interior]
        assert np.allclose(levels, levels.mean(), rtol=1e-3)
        if (~interior).any():
            assert a[~interior].min() >= levels.mean() * (1 - 1e-3)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 60))
def test_isp_beats_rsp_cost_property(seed, n):
    """The ISP solution's cost is never above the RSP solution's cost
    (Lemma 2.1: ISP variance minimizes the bound; both evaluated in the
    shared objective sum a^2/p)."""
    a = jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=0.01, maxval=1.0)
    k = max(2.0, 0.3 * n)
    c_isp = float(solver.expected_cost(a, solver.isp_probabilities(a, k)))
    c_rsp = float(solver.expected_cost(a, solver.rsp_probabilities(a, k)))
    assert c_isp <= c_rsp * (1 + 1e-4)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_optimal_cost_closed_form(seed):
    """eq. 39: when nothing saturates, min cost = (sum a)^2 / K."""
    a = jax.random.uniform(jax.random.PRNGKey(seed), (64,), minval=0.5, maxval=1.0)
    k = 4.0  # K * max(a) <= sum(a) guaranteed: 4*1 <= 32
    got = float(solver.optimal_cost(a, k))
    want = float(jnp.sum(a)) ** 2 / k
    assert abs(got - want) < 1e-2 * want


def test_budget_monotone_cost():
    """More budget -> lower optimal cost (Section 3, asymptotic property)."""
    a = jax.random.uniform(jax.random.PRNGKey(0), (128,), minval=0.01, maxval=1.0)
    costs = [float(solver.optimal_cost(a, float(k))) for k in (2, 8, 32, 64, 128)]
    assert all(c1 >= c2 - 1e-5 for c1, c2 in zip(costs, costs[1:]))
    assert costs[-1] <= float(jnp.sum(a**2)) * (1 + 1e-5)  # K=N: p=1, cost=sum a^2
