"""Unit + property-style tests for the water-filling solvers (Lemmas
2.2/5.1/B.8).  Property sweeps draw (n, K, scale, scores) from seeded
generators across a wide grid of seeds — same invariants the hypothesis
versions checked, no external dependency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver


def test_paper_example_3_2():
    """Paper Section 3, Example 3.2: a = ||g_i|| = [1,3,6], K=2 -> [.25,.75,1]."""
    p = solver.isp_probabilities(jnp.array([1.0, 3.0, 6.0]), 2.0)
    np.testing.assert_allclose(np.asarray(p), [0.25, 0.75, 1.0], atol=1e-6)


def test_k1_reduces_to_rsp():
    """Section 3: with K=1 the ISP solution equals the RSP solution."""
    a = jnp.array([1.0, 3.0, 6.0])
    np.testing.assert_allclose(
        np.asarray(solver.isp_probabilities(a, 1.0)),
        np.asarray(solver.rsp_probabilities(a, 1.0)),
        atol=1e-6,
    )


def test_full_budget_saturates():
    p = solver.isp_probabilities(jnp.array([0.5, 1.0, 2.0, 9.0]), 4.0)
    np.testing.assert_allclose(np.asarray(p), np.ones(4), atol=1e-6)


def test_uniform_scores_give_uniform_probs():
    p = solver.isp_probabilities(jnp.ones(10), 3.0)
    np.testing.assert_allclose(np.asarray(p), np.full(10, 0.3), atol=1e-6)


def test_floor_is_respected():
    a = jnp.array([1e-4, 1.0, 2.0, 3.0])
    p = solver.isp_probabilities(a, 2.0, p_min=0.1)
    assert float(p.min()) >= 0.1 - 1e-7
    assert abs(float(p.sum()) - 2.0) < 1e-5


@pytest.mark.parametrize("seed", range(12))
def test_floor_property_sweep(seed):
    """Lemma 5.1: p in [p_min, 1] and sum(p) == K for random score vectors."""
    rng = np.random.default_rng(3000 + seed)
    n = int(rng.integers(4, 200))
    k = float(max(1.0, rng.uniform(0.05, 0.5) * n))
    p_min = float(rng.uniform(0.0, 0.5) * k / n)  # paper regime: p_min <= K/(2N)
    a = jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=1e-5, maxval=10.0)
    p = solver.isp_probabilities(a, k, p_min=p_min)
    assert float(p.min()) >= p_min - 1e-6
    assert float(p.max()) <= 1.0 + 1e-6
    assert abs(float(p.sum()) - k) < max(1e-3, 1e-4 * k)


def test_mixing_strategy():
    """eq. 12: floor theta*K/N, budget preserved."""
    p = jnp.array([0.0, 0.5, 1.0, 0.5])  # sums to 2
    mixed = solver.mix_probabilities(p, 0.4, 2.0)
    assert abs(float(mixed.sum()) - 2.0) < 1e-6
    assert float(mixed.min()) >= 0.4 * 2.0 / 4 - 1e-7


@pytest.mark.parametrize("seed", range(60))
def test_isp_constraints_property(seed):
    """sum(p) == K, p in (0, 1], for arbitrary positive scores."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 301))
    frac = float(rng.uniform(0.01, 1.0))
    scale = float(rng.uniform(0.01, 100.0))
    k = max(1.0, frac * n)
    a = (
        jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=1e-6, maxval=1.0)
        ** 2
        * scale
    )
    p = solver.isp_probabilities(a, k)
    assert abs(float(jnp.sum(p)) - k) < max(1e-3, 1e-4 * k)
    assert float(jnp.max(p)) <= 1.0 + 1e-6
    assert float(jnp.min(p)) > 0.0


@pytest.mark.parametrize("seed", range(40))
def test_isp_kkt_property(seed):
    """KKT: on the interior, a_i/p_i is constant; capped clients have larger
    a_i than the implied water level."""
    rng = np.random.default_rng(1000 + seed)
    n = int(rng.integers(3, 101))
    k = int(rng.integers(1, 51))
    k = min(k, n - 1)
    a = jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=0.01, maxval=1.0)
    p = np.asarray(solver.isp_probabilities(a, float(k)))
    a = np.asarray(a)
    interior = (p < 1.0 - 1e-6) & (p > 1e-9)
    if interior.sum() >= 2:
        levels = a[interior] / p[interior]
        assert np.allclose(levels, levels.mean(), rtol=1e-3)
        if (~interior).any():
            assert a[~interior].min() >= levels.mean() * (1 - 1e-3)


@pytest.mark.parametrize("seed", range(30))
def test_isp_beats_rsp_cost_property(seed):
    """The ISP solution's cost is never above the RSP solution's cost
    (Lemma 2.1: ISP variance minimizes the bound; both evaluated in the
    shared objective sum a^2/p)."""
    n = int(np.random.default_rng(2000 + seed).integers(3, 61))
    a = jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=0.01, maxval=1.0)
    k = max(2.0, 0.3 * n)
    c_isp = float(solver.expected_cost(a, solver.isp_probabilities(a, k)))
    c_rsp = float(solver.expected_cost(a, solver.rsp_probabilities(a, k)))
    assert c_isp <= c_rsp * (1 + 1e-4)


@pytest.mark.parametrize("seed", range(30))
def test_optimal_cost_closed_form(seed):
    """eq. 39: when nothing saturates, min cost = (sum a)^2 / K."""
    a = jax.random.uniform(jax.random.PRNGKey(seed), (64,), minval=0.5, maxval=1.0)
    k = 4.0  # K * max(a) <= sum(a) guaranteed: 4*1 <= 32
    got = float(solver.optimal_cost(a, k))
    want = float(jnp.sum(a)) ** 2 / k
    assert abs(got - want) < 1e-2 * want


def test_budget_monotone_cost():
    """More budget -> lower optimal cost (Section 3, asymptotic property)."""
    a = jax.random.uniform(jax.random.PRNGKey(0), (128,), minval=0.01, maxval=1.0)
    costs = [float(solver.optimal_cost(a, float(k))) for k in (2, 8, 32, 64, 128)]
    assert all(c1 >= c2 - 1e-5 for c1, c2 in zip(costs, costs[1:]))
    assert costs[-1] <= float(jnp.sum(a**2)) * (1 + 1e-5)  # K=N: p=1, cost=sum a^2
