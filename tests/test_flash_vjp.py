"""Trainable flash attention (custom VJP): gradients match jax.grad(oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "kw",
    [dict(causal=True), dict(causal=True, window=64), dict(causal=True, softcap=30.0),
     dict(causal=False)],
    ids=["causal", "window", "softcap", "full"],
)
def test_flash_vjp_matches_oracle(kw):
    h, s, hd = 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v = (jax.random.normal(ks[i], (h, s, hd)) for i in range(3))
    do = jax.random.normal(ks[3], (h, s, hd))

    out = ops.flash_attention_trainable(
        q, k, v, kw.get("causal", True), kw.get("window"), kw.get("softcap")
    )
    want = ref.mha_reference(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)

    def f_kernel(q, k, v):
        return jnp.sum(
            ops.flash_attention_trainable(
                q, k, v, kw.get("causal", True), kw.get("window"), kw.get("softcap")
            ) * do
        )

    def f_ref(q, k, v):
        return jnp.sum(ref.mha_reference(q, k, v, **kw) * do)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)
