"""Compressed client deltas: quantization properties, the fused
dequant-aggregate kernel vs its jnp oracle (interpret=True on CPU),
error-feedback convergence, spec plumbing, and the segmented/resume
contract under int8 delta width."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CompressionSpec, ExperimentSpec
from repro.core import estimator, make_sampler, sampler_names
from repro.data import synthetic_classification
from repro.fed import FedConfig, logistic_regression, run_federated
from repro.kernels.fused_weighted_agg import (
    _QMAX,
    dequant_cohort_agg_reference,
    dequantize_stacked,
    fused_dequant_cohort_agg,
    quantize_stacked,
)

HAS_FP8 = hasattr(jnp, "float8_e4m3fn")
DTYPES = ["int8"] + (["fp8"] if HAS_FP8 else [])


@pytest.fixture(scope="module")
def tiny_ds():
    return synthetic_classification(n_clients=12, total=600, seed=7)


# ---------------------------------------------------------------- quantizer


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("c,d,sb", [(4, 640, 128), (7, 123, 128), (3, 256, 64)])
def test_quantize_roundtrip_error_bound(dtype, c, d, sb):
    """Blockwise symmetric quantization: padded shapes, per-block fp32
    scales, and a per-element reconstruction error bounded by the block's
    quantization step."""
    flat = jax.random.normal(jax.random.PRNGKey(c * d), (c, d), jnp.float32) * 3.0
    q, scales = quantize_stacked(flat, dtype=dtype, scale_block=sb)
    nb = -(-d // sb)
    assert q.shape == (c, nb * sb) and scales.shape == (c, nb)
    assert scales.dtype == jnp.float32
    assert np.all(np.asarray(scales) > 0)
    deq = np.asarray(dequantize_stacked(q, scales))
    # padding region dequantizes to exact zero
    assert np.array_equal(deq[:, d:], np.zeros((c, nb * sb - d), np.float32))
    err = np.abs(deq[:, :d] - np.asarray(flat))
    step = np.repeat(np.asarray(scales), sb, axis=1)[:, :d]
    if dtype == "int8":
        # round-to-nearest on a scale-wide grid: error <= scale/2 everywhere
        assert np.all(err <= step / 2 + 1e-7)
    else:
        # fp8 e4m3: 3 mantissa bits -> relative error <= 2**-4 of the block max
        assert np.all(err <= step * _QMAX["fp8"] * 2**-4 + 1e-7)


def test_quantize_zero_rows_and_saturation():
    """All-zero slots quantize to zero with the safe scale 1.0 (no NaN/inf on
    dequant), and block abs-max values land exactly on the saturation code."""
    flat = jnp.zeros((2, 256), jnp.float32)
    flat = flat.at[1, 3].set(5.0)
    q, scales = quantize_stacked(flat, dtype="int8", scale_block=128)
    assert np.asarray(scales)[0].tolist() == [1.0, 1.0]
    assert int(np.abs(np.asarray(q)).max()) == 127
    deq = np.asarray(dequantize_stacked(q, scales))
    assert np.all(np.isfinite(deq))
    np.testing.assert_allclose(deq[1, 3], 5.0, rtol=1e-6)
    assert np.array_equal(deq[0], np.zeros(256, np.float32))


# ------------------------------------------------------------ fused kernel


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "c,d,sb,bd",
    [
        (4, 4096, 128, 1024),
        (3, 2048, 128, 2048),
        (8, 1024, 64, 256),
        (2, 512, 128, 512),
    ],
)
def test_fused_dequant_agg_matches_reference(dtype, c, d, sb, bd):
    """The Pallas kernel (interpret=True) and the jnp oracle are the same
    computation: estimate chunk, squared-error scalar, and per-slot
    dequantized squared norms all agree to f32 accumulation tolerance."""
    key = jax.random.PRNGKey(hash((c, d, sb)) % 2**31)
    ks = jax.random.split(key, 3)
    flat = jax.random.normal(ks[0], (c, d), jnp.float32)
    q, scales = quantize_stacked(flat, dtype=dtype, scale_block=sb)
    w = jax.random.uniform(ks[1], (c,), jnp.float32, 0.1, 2.0)
    lam = jax.random.uniform(ks[2], (c,), jnp.float32, 0.0, 0.3)
    got = fused_dequant_cohort_agg(q, scales, w, lam, block_d=bd, interpret=True)
    want = dequant_cohort_agg_reference(q, scales, w, lam)
    for g, r in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=1e-5, atol=1e-5
        )


def test_fused_dequant_agg_close_to_f32_aggregate():
    """End to end, the compressed estimate tracks the uncompressed weighted
    sum within the blockwise quantization error budget."""
    c, d = 5, 2048
    flat = jax.random.normal(jax.random.PRNGKey(0), (c, d), jnp.float32)
    w = jnp.linspace(0.2, 1.4, c)
    q, scales = quantize_stacked(flat, dtype="int8", scale_block=128)
    d_hat, _, sqn = fused_dequant_cohort_agg(
        q, scales, w, jnp.zeros((c,)), block_d=512, interpret=True
    )
    d_true = np.asarray(w @ flat)
    np.testing.assert_allclose(np.asarray(d_hat), d_true, atol=0.05, rtol=0.05)
    true_norms = np.linalg.norm(np.asarray(flat), axis=1)
    np.testing.assert_allclose(np.sqrt(np.asarray(sqn)), true_norms, rtol=0.01)


def test_aggregate_compressed_error_feedback_residual():
    """aggregate_compressed carries the exact quantization error: the applied
    update is d_hat + resid_in and the returned residual is d_true - d_hat,
    so consecutive rounds telescope."""
    c, d = 4, 300
    flat = jax.random.normal(jax.random.PRNGKey(3), (c, d), jnp.float32)
    updates = {"w": flat.reshape(c, 30, 10)}
    w = jnp.linspace(0.5, 1.5, c)
    lam = jnp.full((c,), 0.25)
    comp = CompressionSpec(delta_dtype="int8")
    resid_in = jax.random.normal(jax.random.PRNGKey(4), (d,), jnp.float32) * 0.01
    agg, sq, norms, new_resid = estimator.aggregate_compressed(
        updates, w, lam, comp, resid_in
    )
    d_true = np.asarray(w @ flat)
    applied = np.asarray(agg["w"]).reshape(-1)
    # applied - resid_in is the raw dequantized estimate; adding back the
    # returned residual must reconstruct the exact f32 aggregate
    d_hat = applied - np.asarray(resid_in)
    np.testing.assert_allclose(
        d_hat + np.asarray(new_resid), d_true, rtol=1e-5, atol=1e-5
    )
    assert np.asarray(new_resid).shape == (d,)
    assert float(sq) >= 0.0
    np.testing.assert_allclose(
        np.asarray(norms), np.linalg.norm(flat, axis=1), rtol=0.01
    )


# ------------------------------------------------------------ spec plumbing


def test_compression_spec_roundtrip_and_old_json():
    from repro.api import FederationSpec

    spec = ExperimentSpec(
        federation=FederationSpec(cohort=4),
        compression=CompressionSpec(delta_dtype="int8"),
    )
    d = spec.to_dict()
    assert d["compression"]["delta_dtype"] == "int8"
    back = ExperimentSpec.from_dict(d)
    assert back.compression == spec.compression
    # pre-compression JSONs have no "compression" section -> default disabled
    legacy = spec.to_dict()
    del legacy["compression"]
    old = ExperimentSpec.from_dict(legacy)
    assert old.compression == CompressionSpec()
    assert not old.compression.enabled
    assert old.fed_config().compression is None
    assert old.round_spec().compression is None


def test_compression_spec_validation():
    with pytest.raises(ValueError):
        CompressionSpec(delta_dtype="int4")
    with pytest.raises(ValueError):
        CompressionSpec(delta_dtype="int8", scale_block=0)
    assert not CompressionSpec().enabled
    assert CompressionSpec(delta_dtype="int8").enabled


def test_exact_oracle_equiv_rejects_compression(tiny_ds):
    from repro.fed import server as fed_server

    cfg = FedConfig(
        rounds=2, budget=4, local_steps=1, batch_size=16, seed=0,
        oracle_metrics=False, exact_oracle_equiv=True,
        compression=CompressionSpec(delta_dtype="int8"),
    )
    sampler = make_sampler("uniform_isp", n=tiny_ds.n_clients, budget=4)
    with pytest.raises(ValueError, match="exact_oracle_equiv"):
        run_federated(logistic_regression(), tiny_ds, sampler, cfg)


# ----------------------------------------------------- federated behaviour


def _run(ds, name, rounds=6, compiled=True, **cfg_kw):
    cfg = FedConfig(
        rounds=rounds, budget=4, local_steps=2, batch_size=16, local_lr=0.05,
        seed=11, compiled=compiled, **cfg_kw,
    )
    sampler = make_sampler(
        name, n=ds.n_clients, budget=cfg.budget,
        **({"horizon": cfg.rounds} if name in ("kvib", "vrb") else {}),
    )
    return run_federated(logistic_regression(), ds, sampler, cfg)


@pytest.mark.parametrize("name", sampler_names())
def test_feedback_norms_tolerance_registry_sweep(tiny_ds, name):
    """Registry sweep: with int8 deltas every sampler's feedback signal (the
    dequantized norms driving its score updates) stays within quantization
    tolerance of the f32 run.  Round-1 cohorts are identical (feedback has
    not entered yet), so the post-feedback scores are directly comparable."""
    h32 = _run(tiny_ds, name, rounds=2)
    h8 = _run(tiny_ds, name, rounds=2,
              compression=CompressionSpec(delta_dtype="int8"))
    s32 = np.stack(h32.regret.score_history)
    s8 = np.stack(h8.regret.score_history)
    assert s32.shape == s8.shape
    np.testing.assert_allclose(s8, s32, rtol=0.05, atol=1e-4)
    # losses diverge only by the quantization perturbation
    np.testing.assert_allclose(
        np.asarray(h8.train_loss), np.asarray(h32.train_loss), rtol=0.02, atol=5e-3
    )


def test_error_feedback_recovers_f32_loss(tiny_ds):
    """The acceptance bound: int8 + error feedback lands allclose to the f32
    final loss (the residual telescopes, leaving one round's error), while
    disabling EF accumulates a random walk that is measurably worse."""
    h32 = _run(tiny_ds, "uniform_isp", rounds=25)
    h_ef = _run(tiny_ds, "uniform_isp", rounds=25,
                compression=CompressionSpec(delta_dtype="int8"))
    h_no = _run(tiny_ds, "uniform_isp", rounds=25,
                compression=CompressionSpec(delta_dtype="int8",
                                            error_feedback=False))
    f32 = h32.train_loss[-1]
    ef_err = abs(h_ef.train_loss[-1] - f32)
    no_err = abs(h_no.train_loss[-1] - f32)
    np.testing.assert_allclose(h_ef.train_loss[-1], f32, rtol=0, atol=2e-3)
    assert no_err > 2 * ef_err, (
        f"EF off should drift measurably: |ef|={ef_err:.2e} |no-ef|={no_err:.2e}"
    )


@pytest.mark.parametrize("oracle", [True, False])
def test_compiled_matches_reference_compressed(tiny_ds, oracle):
    """Both execution stacks trace the same compressed round body: compiled
    scan == Python reference loop bitwise, with the EF residual in the carry."""
    kw = dict(rounds=4, oracle_metrics=oracle,
              compression=CompressionSpec(delta_dtype="int8"))
    h_scan = _run(tiny_ds, "kvib", **kw)
    h_py = _run(tiny_ds, "kvib", compiled=False, **kw)
    assert h_scan.train_loss == h_py.train_loss
    assert h_scan.estimator_sq_error == h_py.estimator_sq_error
    for a, b in zip(
        jax.tree_util.tree_leaves(h_scan.final_params),
        jax.tree_util.tree_leaves(h_py.final_params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compressed_segmented_resume_bitwise(tiny_ds, tmp_path):
    """The EF residual is checkpoint state: a compressed run preempted at a
    segment boundary and restored through a CheckpointManager finishes
    bitwise identical to the uninterrupted run."""
    from repro.checkpoint import CheckpointManager
    from repro.fed import build_segment_runner, run_segmented

    cfg = FedConfig(
        rounds=8, budget=4, local_steps=1, batch_size=16, seed=5, ckpt_every=2,
        compression=CompressionSpec(delta_dtype="int8"),
    )
    task = logistic_regression()

    def runner():
        sampler = make_sampler("kvib", n=tiny_ds.n_clients, budget=4, horizon=8)
        return build_segment_runner(task, tiny_ds, sampler, cfg)

    segment, state0 = runner()
    full = run_segmented(state0, cfg.rounds, segment, ckpt_every=cfg.ckpt_every)
    assert full.compression and "resid" in full.compression
    assert np.any(np.asarray(full.compression["resid"]) != 0.0)

    mgr = CheckpointManager(str(tmp_path / "ck"), keep_last=2)
    segment_b, state0_b = runner()
    run_segmented(state0_b, cfg.rounds, segment_b, ckpt_every=cfg.ckpt_every,
                  manager=mgr, max_segments=2)
    segment_c, template = runner()
    restored, step = mgr.restore_or_init(template)
    assert step == 4
    resumed = run_segmented(restored, cfg.rounds, segment_c,
                            ckpt_every=cfg.ckpt_every, manager=mgr)
    for a, b in zip(
        jax.tree_util.tree_leaves(resumed), jax.tree_util.tree_leaves(full)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_disabled_compression_is_inert(tiny_ds):
    """compression=None and an explicit disabled CompressionSpec build the
    SAME program: fed_config() maps disabled -> None, and run histories are
    bitwise equal (the round body has no compression branch to enter)."""
    spec = ExperimentSpec(compression=CompressionSpec())
    assert spec.fed_config().compression is None
    h_none = _run(tiny_ds, "vrb", rounds=4)
    h_off = _run(tiny_ds, "vrb", rounds=4, compression=None)
    assert h_none.train_loss == h_off.train_loss
    for a, b in zip(
        jax.tree_util.tree_leaves(h_none.final_params),
        jax.tree_util.tree_leaves(h_off.final_params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_step_rejects_sequential_compression():
    from repro.configs import get_config
    from repro.fed.round import RoundSpec, build_round_step

    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=64, d_ff=128,
                                            vocab=128)
    cfg = dataclasses.replace(cfg, round_mode="cohort_sequential")
    spec = RoundSpec(cohort=4, local_steps=1, local_lr=0.05,
                     compression=CompressionSpec(delta_dtype="int8"))
    with pytest.raises(ValueError, match="client_parallel"):
        build_round_step(cfg, spec)


def test_zoo_round_step_compressed_matches_f32():
    """The client_parallel zoo round step under int8: same cohort, params
    close to the f32 step within quantization error, EF residual returned."""
    from repro.configs import get_config
    from repro.fed.round import RoundSpec, build_round_step
    from repro.models import transformer

    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=64, d_ff=128,
                                            vocab=128)
    cfg = dataclasses.replace(cfg, round_mode="client_parallel")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    c, r, b, s = 4, 2, 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (c, r, b, s), 0, cfg.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(2), (c, r, b, s), 0, cfg.vocab)
    weights = jnp.array([0.5, 0.0, 1.25, 0.8], jnp.float32)

    step32 = build_round_step(cfg, RoundSpec(cohort=c, local_steps=r,
                                             local_lr=0.05))
    p32, n32, l32 = jax.jit(step32)(params, tokens, targets, weights)

    spec8 = RoundSpec(cohort=c, local_steps=r, local_lr=0.05,
                      compression=CompressionSpec(delta_dtype="int8"))
    step8 = build_round_step(cfg, spec8)
    d_dim = sum(x.size for x in jax.tree_util.tree_leaves(params))
    resid = jnp.zeros((d_dim,), jnp.float32)
    p8, n8, l8, new_resid = jax.jit(step8)(
        params, tokens, targets, weights, resid=resid
    )
    assert float(l8) == float(l32)  # loss is computed pre-aggregation
    np.testing.assert_allclose(np.asarray(n8), np.asarray(n32), rtol=0.02,
                               atol=1e-5)
    assert new_resid.shape == (d_dim,)
    for a, b in zip(jax.tree_util.tree_leaves(p8),
                    jax.tree_util.tree_leaves(p32)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3,
                                   rtol=5e-3)
