"""Sampler behaviour: unbiasedness, variance ordering, constraint invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator, samplers, solver

ALL_SAMPLERS = ["uniform_isp", "uniform_rsp", "kvib", "vrb", "mabs", "avare", "optimal_isp"]


def test_registry_complete_and_exported():
    """Every registered sampler class is exported in __all__ (Osmd and
    ClusteredKVib were registry-only), constructible via make_sampler, and
    every exported Sampler subclass is reachable through the registry."""
    registered = set()
    for name, cls in samplers._REGISTRY.items():
        assert cls.__name__ in samplers.__all__, f"{cls.__name__} missing from __all__"
        s = samplers.make_sampler(name, n=10, budget=3)
        assert isinstance(s, samplers.Sampler)
        registered.add(cls)
    for export in samplers.__all__:
        obj = getattr(samplers, export)
        if isinstance(obj, type) and issubclass(obj, samplers.Sampler) and obj is not samplers.Sampler:
            assert obj in registered, f"{export} exported but not registered"


def test_cohort_width_entry_points_exported():
    """The cohort-width aggregation surface AND the segmented-horizon /
    checkpoint subsystem reach users through the package __all__s: estimator
    entry points via repro.core, the scan/round/segment entry points via
    repro.fed, the Pallas kernels via repro.kernels, the checkpoint API via
    repro.checkpoint, and the declarative spec front door via repro.api
    (whose names are also re-exported from top-level repro)."""
    import repro
    import repro.analysis as analysis
    import repro.api as api
    import repro.checkpoint as checkpoint
    import repro.core as core
    import repro.fed as fed
    import repro.kernels as kernels

    for pkg, names in (
        (core, ("aggregate_and_error", "aggregate_and_error_cohort",
                "aggregate_compressed", "assert_serializable_state",
                "sampler_names")),
        (fed, ("RoundSpec", "build_fed_scan", "build_fed_scan_segment",
               "build_round_step", "build_segment_runner", "run_segmented",
               "TrainState", "round_body_for_lint", "scan_body_for_lint")),
        (kernels, ("fused_multi_weighted_agg", "fused_cohort_agg_and_error",
                   "fused_dequant_cohort_agg", "quantize_stacked",
                   "dequantize_stacked")),
        (checkpoint, ("save_checkpoint", "restore_checkpoint",
                      "CheckpointManager", "config_fingerprint")),
        (api, ("ExperimentSpec", "TaskSpec", "SamplerSpec", "FederationSpec",
               "ExecutionSpec", "CompressionSpec", "run", "build",
               "restore_template", "register_task", "register_dataset",
               "lint")),
        (analysis, ("analyze_hlo", "dtype_bytes", "UnknownDtypeError",
                    "Finding", "LintReport", "audit_width", "audit_width_hlo",
                    "audit_scan_safety", "audit_dtypes", "audit_compile_once",
                    "run_suite", "sweep_registry")),
    ):
        for name in names:
            assert name in pkg.__all__, f"{pkg.__name__}.__all__ missing {name}"
            assert callable(getattr(pkg, name)), f"{pkg.__name__}.{name} not callable"
    # the spec surface is importable from top-level repro (lazy PEP 562)
    for name in ("ExperimentSpec", "TaskSpec", "SamplerSpec", "FederationSpec",
                 "ExecutionSpec", "run", "build"):
        assert name in repro.__all__
        assert getattr(repro, name) is getattr(api, name)
    # module-level __all__s agree with what the packages re-export
    assert "aggregate_and_error_cohort" in estimator.__all__
    import importlib

    # the package re-exports the FUNCTION under the module's name, so reach
    # the module itself through importlib
    fwa_mod = importlib.import_module("repro.kernels.fused_weighted_agg")
    assert "fused_cohort_agg_and_error" in fwa_mod.__all__
    assert "fused_dequant_cohort_agg" in fwa_mod.__all__
    assert "quantize_stacked" in fwa_mod.__all__
    mgr_mod = importlib.import_module("repro.checkpoint.manager")
    assert "CheckpointManager" in mgr_mod.__all__ and "config_fingerprint" in mgr_mod.__all__
    assert "assert_serializable_state" in samplers.__all__
    # the lint module itself is reachable lazily (PEP 562) but is a module,
    # not a callable — membership only
    assert "lint" in analysis.__all__
    import types

    assert isinstance(analysis.lint, types.ModuleType)


@pytest.mark.parametrize("name", samplers.sampler_names())
def test_serializable_state_contract_registry_sweep(name):
    """Every registered sampler's init() state passes the serializable-state
    contract, and the contract's dtype half rejects f64 and weak-typed leaves
    (both change carry avals across a checkpoint round trip — the failure the
    compile-once lint guard would otherwise catch only at resume)."""
    import dataclasses

    n = 12
    st = samplers.make_sampler(name, n=n, budget=4).init()
    samplers.assert_serializable_state(st)

    wide = dataclasses.replace(st, stats=np.zeros(n, np.float64))
    with pytest.raises(TypeError, match="float64"):
        samplers.assert_serializable_state(wide)

    weak = dataclasses.replace(st, t=jnp.asarray(0.0))
    assert weak.t.weak_type
    with pytest.raises(TypeError, match="weak-typed"):
        samplers.assert_serializable_state(weak)


@pytest.mark.parametrize("name", ALL_SAMPLERS)
def test_roundtrip_and_constraints(name):
    n, k = 40, 8
    s = samplers.make_sampler(name, n=n, budget=k)
    st_ = s.init()
    key = jax.random.PRNGKey(0)
    fb_full = jax.random.uniform(jax.random.PRNGKey(1), (n,), minval=0.1, maxval=1.0)
    for t in range(6):
        key, sub = jax.random.split(key)
        draw = s.sample(st_, sub)
        assert draw.mask.shape == (n,)
        assert draw.counts.dtype == jnp.int32
        st_ = s.update(st_, draw, fb_full * draw.mask)
    p = s.probabilities(st_)
    assert p.shape == (n,)
    assert float(p.min()) > 0.0
    if s.procedure == "isp":
        assert abs(float(p.sum()) - k) < 1e-3 * k, f"{name}: ISP marginals must sum to K"
        assert float(p.max()) <= 1.0 + 1e-6
    else:
        # RSP draw distributions are normalized.
        dp = s.probabilities(st_)
        if name != "uniform_rsp":
            assert abs(float(dp.sum()) - 1.0) < 1e-5


@pytest.mark.parametrize("name", ["uniform_isp", "kvib", "vrb", "mabs", "avare", "uniform_rsp"])
def test_estimator_unbiased_statistically(name):
    """Definition 2.1: E[d^t] == sum_i lambda_i g_i for every sampler."""
    n, k, d = 24, 6, 16
    g = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    lam = jax.random.dirichlet(jax.random.PRNGKey(3), jnp.ones(n))
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))

    s = samplers.make_sampler(name, n=n, budget=k)
    st_ = s.init()
    # burn-in so adaptive states are non-trivial
    fb = lam * jnp.linalg.norm(g, axis=1)
    for t in range(3):
        draw = s.sample(st_, jax.random.PRNGKey(50 + t))
        st_ = s.update(st_, draw, fb * draw.mask)

    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(7), trials)

    def one(key):
        draw = s.sample(st_, key)
        w = estimator.client_weights(draw, lam, s.procedure, s.budget)
        return estimator.aggregate_stacked(g, w)

    ests = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, axis=0))
    se = np.asarray(jnp.std(ests, axis=0)) / np.sqrt(trials)
    # 5-sigma elementwise test
    assert np.all(np.abs(mean - target) < 5.0 * se + 1e-4), name


def test_isp_variance_below_rsp_empirically():
    """Lemma 2.1 / Figure 1: for identical adaptive marginals, the ISP
    estimator's empirical variance is below the RSP(with-replacement) one."""
    n, k, d = 30, 8, 64
    g = jax.random.normal(jax.random.PRNGKey(0), (n, d)) * jnp.linspace(
        0.2, 3.0, n
    ).reshape(n, 1)
    lam = jnp.ones((n,)) / n
    scores = lam * jnp.linalg.norm(g, axis=1)
    p_isp = solver.isp_probabilities(scores, float(k))
    target = estimator.full_aggregate_stacked(g, lam)

    trials = 3000

    def isp_err(key):
        draw = samplers._isp_draw(key, p_isp)
        w = estimator.client_weights(draw, lam, "isp", k)
        est = estimator.aggregate_stacked(g, w)
        return estimator.empirical_sq_error(est, target)

    q = scores / scores.sum()

    def rsp_err(key):
        draw = samplers._rsp_wr_draw(key, q, k)
        w = estimator.client_weights(draw, lam, "rsp_wr", k)
        est = estimator.aggregate_stacked(g, w)
        return estimator.empirical_sq_error(est, target)

    keys = jax.random.split(jax.random.PRNGKey(5), trials)
    v_isp = float(jnp.mean(jax.vmap(isp_err)(keys)))
    v_rsp = float(jnp.mean(jax.vmap(rsp_err)(keys)))
    assert v_isp < v_rsp, (v_isp, v_rsp)
    # And the analytic ISP variance formula matches the empirical one.
    v_analytic = float(estimator.isp_variance(scores, p_isp))
    assert abs(v_isp - v_analytic) / v_analytic < 0.15


def test_isp_expected_cohort_size():
    """Section 3: |S^t| is random with E|S| = K under ISP."""
    n, k = 100, 20
    s = samplers.make_sampler("uniform_isp", n=n, budget=k)
    st_ = s.init()
    sizes = []
    for t in range(500):
        draw = s.sample(st_, jax.random.PRNGKey(t))
        sizes.append(int(draw.size))
    sizes = np.asarray(sizes)
    assert abs(sizes.mean() - k) < 0.5
    assert sizes.std() > 0.5  # genuinely stochastic


def test_kvib_probabilities_track_feedback():
    """Clients with persistently larger feedback get larger p under K-Vib."""
    n, k = 32, 8
    s = samplers.make_sampler("kvib", n=n, budget=k, horizon=200, gamma=1e-4)
    st_ = s.init()
    fb_full = jnp.linspace(0.05, 1.0, n)  # client i feedback ~ i
    key = jax.random.PRNGKey(0)
    for t in range(100):
        key, sub = jax.random.split(key)
        draw = s.sample(st_, sub)
        st_ = s.update(st_, draw, fb_full * draw.mask)
    p = np.asarray(s.probabilities(st_))
    # Spearman-ish: top-quartile clients should have higher mean p than bottom.
    assert p[-8:].mean() > 1.5 * p[:8].mean()


def test_kvib_regret_decreases_with_budget():
    """Theorem 5.2 (Figure 3b): per-round regret shrinks as K grows."""
    n, T = 64, 120
    rng = np.random.default_rng(0)
    base = rng.uniform(0.1, 1.0, size=n).astype(np.float32)

    def run(k):
        s = samplers.make_sampler("kvib", n=n, budget=k, horizon=T, gamma=None)
        st_ = s.init()
        key = jax.random.PRNGKey(1)
        reg = 0.0
        for t in range(T):
            fb_full = jnp.asarray(base * (1.0 + 0.05 * rng.standard_normal(n).astype(np.float32)))
            key, sub = jax.random.split(key)
            p = s.probabilities(st_)
            draw = s.sample(st_, sub)
            cost = float(solver.expected_cost(fb_full, p))
            opt = float(solver.optimal_cost(fb_full, float(k)))
            reg += cost - opt
            st_ = s.update(st_, draw, fb_full * draw.mask)
        return reg / T

    r8, r32 = run(8), run(32)
    assert r32 < r8, (r8, r32)


@pytest.mark.parametrize("seed", range(20))
def test_client_weights_nonnegative_and_sparse(seed):
    n, k = 50, 10
    s = samplers.make_sampler("kvib", n=n, budget=k, gamma=0.1)
    st_ = s.init()
    draw = s.sample(st_, jax.random.PRNGKey(seed))
    lam = jnp.ones(n) / n
    w = estimator.client_weights(draw, lam, "isp", k)
    w = np.asarray(w)
    assert (w >= 0).all()
    assert (w[~np.asarray(draw.mask)] == 0).all()
