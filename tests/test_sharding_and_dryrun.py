"""Sharding-rule assignment + dry-run machinery smoke (small mesh, subprocess)."""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _specs_for(arch, mesh, fsdp):
    from repro.launch.sharding import param_specs

    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    return shapes, param_specs(shapes, mesh, fsdp=fsdp)


def test_param_spec_roles_dense(mesh11):
    shapes, specs = _specs_for("llama3.2-1b", mesh11, fsdp=False)
    blk = specs["stacks"][0]
    # expanding projections: last dim model
    assert blk["attn"]["wq"] == jax.sharding.PartitionSpec(None, None, "model")
    assert blk["mlp"]["up"][-1] == "model"
    # contracting projections: second-to-last dim model
    assert blk["attn"]["wo"][-2] == "model"
    assert blk["mlp"]["down"][-2] == "model"
    # embeddings: vocab over model
    assert specs["embed"][0] == "model"
    # norms replicated
    assert specs["final_norm"] == jax.sharding.PartitionSpec()


def test_param_spec_roles_moe_fsdp(mesh11):
    shapes, specs = _specs_for("arctic-480b", mesh11, fsdp=True)
    P = jax.sharding.PartitionSpec
    blk = specs["stacks"][0]
    # expert stacks: expert axis over model, d over the fsdp axes
    assert blk["moe"]["w_up"] == P(None, "model", ("data",), None)
    assert blk["moe"]["w_down"] == P(None, "model", None, ("data",))
    # dense-residual branch present and sharded
    assert blk["moe"]["dense"]["up"] == P(None, ("data",), "model")


def test_cache_sharding_heuristics(mesh11):
    from repro.launch.sharding import cache_shardings

    cfg = get_config("llama3.2-1b")
    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, 128, 1024))
    shardings = cache_shardings(caches, mesh11, max_seq=1024, batch=128)
    k_spec = shardings[0]["k"].spec
    # (reps, B, S, KV, hd): batch -> data axes, seq -> model
    assert k_spec == jax.sharding.PartitionSpec(None, ("data",), "model", None, None)


@pytest.mark.slow
def test_dryrun_machinery_small_mesh_subprocess():
    """The full deliverable-(e) path (lower+compile+analyses) on a 4x4 mesh
    of 16 host devices — fast enough for CI, same code as production."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-125m", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
            "REPRO_DRYRUN_DEVICES": "16", "REPRO_MESH_SHAPE": "4,4",
        },
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    result = json.loads(proc.stdout.strip().splitlines()[-1])
    assert result["status"] == "ok"
    assert result["kind"] == "decode"
    assert result["flops"] > 0
    assert result["bytes_accessed"] > 0
    assert result["memory"]["argument_size_bytes"] > 0
