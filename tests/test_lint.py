"""The trace-invariant lint suite (repro.analysis.lint).

Two directions, both required for the auditors to be trustworthy:

* seeded violations — a deliberately O(N*D) round body, a sampler with a
  hidden ``io_callback``, and an f64 leak must each produce EXACTLY ONE
  finding naming the offending op with real source provenance (origin
  filtering: downstream consumers of an already-flagged buffer are not
  re-reported);
* clean programs — the repo's own bodies, samplers, and segment runners must
  sweep clean, which is what the CI gate (``python -m repro.analysis.lint``)
  enforces over the full registry x oracle/deployable x compiled/reference
  matrix (mirrored here as a ``slow`` test).
"""
import dataclasses

import jax
import jax.experimental
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.lint import (
    Finding,
    LintReport,
    audit_compile_once,
    audit_dtypes,
    audit_scan_safety,
    audit_width,
    audit_width_hlo,
    main,
    run_suite,
    sweep_registry,
)
from repro.api import (
    ExecutionSpec,
    ExperimentSpec,
    FederationSpec,
    SamplerSpec,
    TaskSpec,
)
from repro.core import samplers

N = 13  # distinctive client count: prime, collides with no model dimension
D = 60


def _spec(**exec_kw):
    return ExperimentSpec(
        task=TaskSpec(
            name="logreg",
            dataset="synthetic_classification",
            dataset_kwargs={"n_clients": N, "total": 40 * N, "seed": 0},
        ),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 4}),
        federation=FederationSpec(rounds=4, budget=4, local_steps=1, batch_size=8),
        execution=ExecutionSpec(**exec_kw),
    )


# ---------------------------------------------------------------------------
# Seeded violations: exactly one finding each, right op, real provenance
# ---------------------------------------------------------------------------


def test_seeded_ond_body_yields_exactly_one_width_finding():
    """An outer product materializing (N, D) must be flagged once, at the
    multiply that introduces it — its downstream sum consumes the flagged
    buffer and is suppressed by origin filtering."""

    def bad_body(fb, delta):
        contrib = fb[:, None] * delta[None, :]  # the O(N*D) leak
        return jnp.sum(contrib, axis=0)

    closed = jax.make_jaxpr(bad_body)(
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((D,), jnp.float32),
    )
    findings = audit_width(closed, N, target="bad_body")
    assert len(findings) == 1, "\n".join(f.render() for f in findings)
    (f,) = findings
    assert f.check == "width"
    assert f.op == "mul"
    assert f.shape == f"float32[{N},{D}]"
    assert "test_lint.py" in f.provenance and "bad_body" in f.provenance


def test_width_auditor_allows_n_vectors_and_integer_buffers():
    """(N,) float vectors (probabilities, feedback) and N-sized integer/key
    material ((N, R, 2) uint32 batch keys) are legitimate — no findings."""

    def fine_body(p, key):
        fb = p * 2.0  # (N,) float: fine
        keys = jax.vmap(lambda k: jax.random.split(k, 3))(
            jax.random.split(key, N)
        )  # (N, 3, 2) uint32: fine (not float)
        return fb.sum() + keys.sum()

    closed = jax.make_jaxpr(fine_body)(
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    assert audit_width(closed, N) == []


def test_width_auditor_allowlist_permits_declared_buffers():
    def body(fb, delta):
        return fb[:, None] * delta[None, :]

    closed = jax.make_jaxpr(body)(
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((D,), jnp.float32),
    )
    assert audit_width(closed, N, allow=[(N, D)]) == []
    assert len(audit_width(closed, N)) == 1


def test_seeded_callback_sampler_yields_exactly_one_scan_safety_finding():
    """A sampler smuggling an io_callback into update() is rejected with one
    finding naming the callback primitive and the method."""

    @dataclasses.dataclass(frozen=True)
    class SpySampler(samplers.Sampler):
        def update(self, state, draw, feedback):
            jax.experimental.io_callback(
                lambda x: None, None, feedback, ordered=True
            )
            return dataclasses.replace(state, t=state.t + 1)

    findings = audit_scan_safety(SpySampler(n=N, budget=4))
    assert len(findings) == 1, "\n".join(f.render() for f in findings)
    (f,) = findings
    assert f.check == "scan_safety"
    assert f.op == "io_callback"
    assert f.target.endswith(".update")
    assert "test_lint.py" in f.provenance


def test_seeded_f64_leak_yields_exactly_one_dtype_finding():
    """An astype(float64) leak is flagged once, at the convert that
    introduces the wide dtype — the arithmetic consuming it is suppressed."""

    def leaky(x):
        y = x.astype(jnp.float64)
        return (y * 2.0).sum()

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(leaky)(jax.ShapeDtypeStruct((N,), jnp.float32))
    findings = audit_dtypes(closed, target="leaky")
    assert len(findings) == 1, "\n".join(f.render() for f in findings)
    (f,) = findings
    assert f.check == "dtype"
    assert f.op == "convert_element_type"
    assert f.shape == f"float64[{N}]"
    assert "test_lint.py" in f.provenance and "leaky" in f.provenance


def test_data_dependent_control_flow_surfaces_as_finding():
    @dataclasses.dataclass(frozen=True)
    class BranchySampler(samplers.Sampler):
        def probabilities(self, state):
            if state.stats[0] > 0:  # tracer bool conversion at trace time
                return jnp.full((self.n,), 0.5)
            return jnp.full((self.n,), self.budget / self.n)

    findings = audit_scan_safety(BranchySampler(n=N, budget=4))
    assert len(findings) == 1
    (f,) = findings
    assert f.check == "scan_safety" and f.target.endswith(".probabilities")
    assert "control flow" in f.message


def test_update_aval_drift_surfaces_as_finding():
    """update() silently retyping a state leaf breaks the scan carry on the
    next round; the checker reports it at the sampler, statically."""

    @dataclasses.dataclass(frozen=True)
    class DriftySampler(samplers.Sampler):
        def update(self, state, draw, feedback):
            return dataclasses.replace(
                state, t=(state.t + 1).astype(jnp.float32)
            )

    findings = audit_scan_safety(DriftySampler(n=N, budget=4))
    assert len(findings) == 1
    assert "drifts state leaf" in findings[0].message


def test_bad_probabilities_shape_surfaces_as_finding():
    @dataclasses.dataclass(frozen=True)
    class WideProbs(samplers.Sampler):
        def probabilities(self, state):
            return jnp.full((self.n, 2), 0.5)

    findings = audit_scan_safety(WideProbs(n=N, budget=4))
    assert len(findings) == 1
    assert "probabilities must return" in findings[0].message


# ---------------------------------------------------------------------------
# HLO-level width audit
# ---------------------------------------------------------------------------


def test_hlo_width_audit_flags_compiled_leak_and_passes_clean_body():
    def bad(fb, delta):
        return (fb[:, None] * delta[None, :]).sum(axis=0)

    def fine(fb, delta):
        return fb.sum() * delta

    args = (
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((D,), jnp.float32),
    )
    bad_text = jax.jit(bad).lower(*args).compile().as_text()
    fine_text = jax.jit(fine).lower(*args).compile().as_text()
    bad_findings = audit_width_hlo(bad_text, N, target="bad")
    assert bad_findings, "compiled O(N*D) buffer must be visible in HLO"
    assert all(f.check == "width" for f in bad_findings)
    assert audit_width_hlo(fine_text, N, target="fine") == []


# ---------------------------------------------------------------------------
# Compile-once guard
# ---------------------------------------------------------------------------


def _toy_segment(params0, rounds=6):
    from repro.fed.state import TrainState, init_metric_buffers, make_segment_fn

    def body(carry, xs):
        p, s = carry
        return (p + 1.0, s), {"loss": jnp.sum(p)}

    def derive(k, _):
        k2, kd = jax.random.split(k)
        return k2, jnp.stack([kd, kd])

    seg = make_segment_fn(body, derive, with_opt_state=False, with_round_index=False)
    key = jax.random.PRNGKey(0)
    s0 = jnp.zeros((3,), jnp.float32)
    state = TrainState(
        params=params0,
        opt_state=(),
        sampler=s0,
        metrics=init_metric_buffers(
            body, (params0, s0), jnp.stack([key, key]), rounds
        ),
        round=jnp.zeros((), jnp.int32),
        key=key,
    )
    return seg, state


def test_compile_once_clean_on_strong_typed_carry():
    seg, state = _toy_segment(jnp.zeros((4,), jnp.float32))
    assert audit_compile_once(seg, state, 2) == []


def test_compile_once_flags_weak_typed_carry_on_resume():
    """A weak-typed carry leaf survives segment boundaries but not the numpy
    round trip a checkpoint applies — the guard must catch the resume
    recompile that causes."""
    params0 = jnp.asarray(1.0)  # python-scalar conversion: weak_type=True
    assert params0.weak_type
    seg, state = _toy_segment(params0)
    findings = audit_compile_once(seg, state, 2)
    assert len(findings) == 1
    assert findings[0].check == "compile_once"
    assert "resume recompiles" in findings[0].message


def test_compile_once_flags_declared_donation_mismatch():
    seg, state = _toy_segment(jnp.zeros((4,), jnp.float32))
    tampered = dict(seg._lint)
    tampered["donate_argnums"] = (0,) if not tampered["donate_argnums"] else ()
    seg._lint = tampered
    findings = audit_compile_once(seg, state, 2, resume=False)
    assert any("donation mismatch" in f.message for f in findings)


def test_compile_once_clean_on_real_segment_runner():
    """The actual fed.server segmented runner: one compile across segments
    and across the checkpoint-transport round trip."""
    from repro.data import synthetic_classification
    from repro.fed import FedConfig, logistic_regression
    from repro.fed.server import build_segment_runner

    ds = synthetic_classification(n_clients=N, total=40 * N, seed=0)
    cfg = FedConfig(rounds=6, budget=4, local_steps=1, batch_size=8,
                    oracle_metrics=False)
    sampler = samplers.make_sampler("kvib", n=N, budget=4, horizon=6)
    segment, state = build_segment_runner(
        logistic_regression(), ds, sampler, cfg, None
    )
    assert audit_compile_once(segment, state, 2, target="segment") == []


# ---------------------------------------------------------------------------
# Registry-wide scan-safety + the suite front door
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", samplers.sampler_names())
def test_registered_samplers_are_scan_safe(name):
    s = samplers.make_sampler(name, n=N, budget=4)
    findings = audit_scan_safety(s, target=f"sampler:{name}")
    assert findings == [], "\n".join(f.render() for f in findings)


def test_run_suite_clean_on_deployable_compiled_spec():
    """The front door on a real spec: all five passes (scan-safety, dtype,
    jaxpr width, compile-once, HLO width) run and come back clean."""
    report = run_suite(_spec(compiled=True, oracle_metrics=False))
    assert report.ok, report.render()
    kinds = {c.split(":", 1)[0] for c in report.checked}
    assert kinds == {"scan_safety", "dtype", "width", "compile_once", "width_hlo"}


def test_run_suite_skips_width_on_oracle_and_scatter_bodies():
    rep_oracle = run_suite(_spec(compiled=False, oracle_metrics=True))
    assert rep_oracle.ok, rep_oracle.render()
    assert not any(c.startswith("width") for c in rep_oracle.checked)
    rep_scatter = run_suite(
        _spec(compiled=False, oracle_metrics=False, exact_oracle_equiv=True)
    )
    assert rep_scatter.ok, rep_scatter.render()
    assert not any(c.startswith("width") for c in rep_scatter.checked)


def test_api_lint_wrapper_forwards_to_run_suite():
    import repro.api as api

    report = api.lint(_spec(compiled=False), hlo=False, compile_guard=False)
    assert isinstance(report, LintReport)
    assert report.ok, report.render()


def test_report_render_and_ok():
    rep = LintReport()
    rep.add([], "width:x")
    assert rep.ok and "clean" in rep.render()
    rep.add(
        [Finding(check="width", target="t", message="boom", op="mul",
                 shape="float32[13,60]")],
        "width:y",
    )
    assert not rep.ok
    text = rep.render()
    assert "1 finding" in text and "mul" in text and "boom" in text


def test_cli_single_sampler_fast_sweep_exit_codes(tmp_path, capsys):
    """main() is the ``python -m repro.analysis.lint`` entry point: 0 on a
    clean sweep/spec, nonzero would mean a finding."""
    rc = main(["--samplers", "uniform_isp", "--fast", "--quiet"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "lint clean" in out

    path = tmp_path / "spec.json"
    _spec(compiled=False).save(path)
    assert main(["--spec", str(path)]) == 0


def test_hlo_unknown_dtype_is_a_named_error():
    """analysis.hlo used to KeyError on unknown dtype tokens deep inside
    byte accounting; now it's a catchable, self-describing error."""
    from repro.analysis.hlo import DTYPE_BYTES, UnknownDtypeError, dtype_bytes

    assert dtype_bytes("f32") == 4
    with pytest.raises(UnknownDtypeError) as ei:
        dtype_bytes("f4e2m1")
    assert ei.value.dtype == "f4e2m1"
    assert "DTYPE_BYTES" in str(ei.value)
    assert isinstance(ei.value, KeyError)  # backward-compatible except clauses
    assert set(DTYPE_BYTES) >= {"f32", "bf16", "s32", "pred"}


@pytest.mark.slow  # the CI gate: full registry x fidelity x mode, with compiles
def test_full_registry_sweep_is_clean():
    report = sweep_registry()
    assert report.ok, report.render()
    # 9 samplers x 2 fidelities x 2 modes, every cell at least scan-safety +
    # dtype checked
    assert len(report.checked) >= 9 * 2 * 2 * 2
