"""Per-architecture smoke tests: REDUCED variants (<=2-ish pattern groups,
d_model<=128, <=4 experts) run one forward + one train-grad step + a decode
step on CPU, asserting shapes and no NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer

ARCHS = list_archs()


def _reduced(name):
    cfg = get_config(name).reduced()
    return cfg


def _batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (b, s), 0, cfg.vocab)
    targets = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
    if cfg.frontend:
        fd = cfg.frontend_dim or cfg.d_model
        aux = jax.random.normal(ks[2], (b, cfg.frontend_seq, fd), jnp.float32)
        return (tokens, targets, aux)
    return (tokens, targets)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = _reduced(name)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = transformer.forward(
        params, cfg, batch[0], batch[2] if len(batch) > 2 else None
    )
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_no_nans(name):
    cfg = _reduced(name)
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(cfg, key)
    batch = _batch(cfg, key)

    loss, grads = jax.value_and_grad(lambda p: transformer.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), name
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), name
    # SGD step changes params
    new = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    l2 = transformer.loss_fn(new, cfg, batch)
    assert np.isfinite(float(l2))


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_forward(name):
    """Prefill + single decode step must agree with the full forward on the
    next-token logits (the serving path is consistent with training math)."""
    cfg = _reduced(name)
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(cfg, key)
    b, s = 2, 16
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    aux = None
    if cfg.frontend:
        fd = cfg.frontend_dim or cfg.d_model
        aux = jax.random.normal(key, (b, cfg.frontend_seq, fd), jnp.float32)

    # ground truth: full forward over s+1 tokens; logits at position s-1
    # predict token s.
    logits_full, _ = transformer.forward(params, cfg, tokens, aux)

    logits_pre, caches = transformer.prefill(params, cfg, tokens[:, :s], aux, max_seq=s + 4)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, s - 1], np.float32),
        rtol=2e-2,
        atol=2e-2,
    )

    logits_dec, caches = transformer.decode_step(
        params, cfg, tokens[:, s : s + 1], caches, jnp.asarray(s, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, s], np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


def test_long_variant_exists_for_llama1b():
    from repro.configs.llama3_2_1b import SW_CONFIG

    assert SW_CONFIG.block_pattern == ("attn_local",)
    assert SW_CONFIG.sliding_window == 8192


def test_param_counts_full_configs():
    """Full configs must hit their nameplate scale (+-35%) — catches config
    transcription errors without allocating (eval_shape only)."""
    import jax

    expectations = {
        "llama3-405b": 405e9,
        "qwen3-moe-235b-a22b": 235e9,
        "arctic-480b": 480e9,
        "gemma2-27b": 27e9,
        "llama3.2-1b": 1.2e9,
        "smollm-360m": 360e6,
        "xlstm-125m": 125e6,
        "zamba2-1.2b": 1.2e9,
        "llama-3.2-vision-11b": 11e9,
        "whisper-small": 240e6,
    }
    for name, want in expectations.items():
        cfg = get_config(name)
        shapes = jax.eval_shape(
            lambda c=cfg: transformer.init_params(c, jax.random.PRNGKey(0))
        )
        n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(shapes))
        assert 0.65 * want < n < 1.45 * want, f"{name}: {n/1e9:.2f}B vs {want/1e9:.2f}B"
