"""The benchmark regression gate: committed BENCH_*.json ratio baselines must
survive a re-run on this host within the 2x budget (benchmarks/check_regression).
"""
import pytest


def test_baselines_have_ratio_dicts():
    """Tier-1 sanity: the committed artifacts carry the lower-is-better
    ``ratios`` dicts the gate compares (no bench re-run needed)."""
    from benchmarks.check_regression import iter_baselines

    baselines = dict(iter_baselines())
    assert "fed_cohort_width" in baselines
    assert "fed_round_cohort" in baselines
    assert "fed_scan_segmented" in baselines
    for name, ratios in baselines.items():
        for key, val in ratios.items():
            assert isinstance(val, float) and val > 0, f"{name}:{key} = {val!r}"


@pytest.mark.slow  # re-times every ratio-bearing benchmark on this host
def test_bench_ratios_within_regression_budget():
    from benchmarks.check_regression import check_all

    failures = check_all()
    assert not failures, "\n".join(failures)
