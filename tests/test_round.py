"""Distributed round-step semantics (single-device CPU execution).

The two cohort execution modes are different *schedules* of the same math:
given identical params, batches, and ISP weights, client_parallel and
cohort_sequential must produce the same new params and feedback norms.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.fed.round import RoundSpec, build_round_step
from repro.models import transformer


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=64, d_ff=128, vocab=128)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    c, r, b, s = 4, 2, 2, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (c, r, b, s), 0, cfg.vocab)
    targets = jax.random.randint(jax.random.PRNGKey(2), (c, r, b, s), 0, cfg.vocab)
    weights = jnp.array([0.5, 0.0, 1.25, 0.8], jnp.float32)  # one masked-out client
    return cfg, params, tokens, targets, weights


def _run(cfg, mode, params, tokens, targets, weights):
    cfg2 = dataclasses.replace(cfg, round_mode=mode)
    spec = RoundSpec(cohort=tokens.shape[0], local_steps=tokens.shape[1], local_lr=0.05)
    step = build_round_step(cfg2, spec)
    return jax.jit(step)(params, tokens, targets, weights)


def test_modes_agree(setup):
    cfg, params, tokens, targets, weights = setup
    p1, n1, l1 = _run(cfg, "client_parallel", params, tokens, targets, weights)
    p2, n2, l2 = _run(cfg, "cohort_sequential", params, tokens, targets, weights)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-4, rtol=2e-3
        )


def test_masked_client_contributes_nothing(setup):
    """w_c = 0 (cohort padding / unsampled) must not affect d^t."""
    cfg, params, tokens, targets, weights = setup
    p1, _, _ = _run(cfg, "client_parallel", params, tokens, targets, weights)
    # perturb the masked client's data; result must be identical
    tokens2 = tokens.at[1].set((tokens[1] + 7) % cfg.vocab)
    p2, _, _ = _run(cfg, "client_parallel", params, tokens2, targets, weights)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_round_is_unbiased_fedavg_direction(setup):
    """With w = lambda (full participation), the round reproduces FedAvg:
    x_new = x - sum_i lambda_i g_i."""
    cfg, params, tokens, targets, _ = setup
    lam = jnp.full((4,), 0.25, jnp.float32)
    p_round, norms, _ = _run(cfg, "client_parallel", params, tokens, targets, lam)

    # manual reference
    from repro.fed.round import _local_train

    deltas = []
    for c in range(4):
        d, _ = _local_train(
            params, cfg, (tokens[c], targets[c]), 0.05
        )
        deltas.append(d)
    ref = jax.tree_util.tree_map(
        lambda p, *ds: p - sum(0.25 * d.astype(jnp.float32) for d in ds).astype(p.dtype),
        params,
        *deltas,
    )
    for a, b in zip(jax.tree_util.tree_leaves(p_round), jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-4, rtol=2e-3
        )
    # feedback norms are the true update norms
    from repro.fed.client import update_norm

    for c in range(4):
        np.testing.assert_allclose(
            float(norms[c]), float(update_norm(deltas[c])), rtol=1e-4
        )
