"""The checkpoint subsystem: atomic step-numbered writes, manifest discovery,
retention, config/treedef validation — and the sampler serializable-state
contract swept over the whole registry (save -> restore into a fresh template
-> continue must be bitwise-equal to never having round-tripped).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    config_fingerprint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core import samplers


# ---------------------------------------------------------------------------
# checkpointer.py satellites: strict dtype, treedef read-back, atomic sidecar
# ---------------------------------------------------------------------------


def test_restore_rejects_dtype_mismatch(tmp_path):
    """Dtype drift raises like shape drift does — no silent astype."""
    f = save_checkpoint(str(tmp_path / "c"), {"a": jnp.zeros((3,), jnp.float32)})
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(f, {"a": np.zeros((3,), np.float64)})
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(f, {"a": np.zeros((3,), np.int32)})


def test_restore_compares_saved_treedef(tmp_path):
    """The .treedef.txt sidecar is actually read back: a template with the
    same leaf count/shapes/dtypes but a different STRUCTURE must raise
    (before this fix, only leaf count was checked)."""
    f = save_checkpoint(
        str(tmp_path / "c"), {"a": jnp.zeros((3,)), "b": jnp.ones((3,))}
    )
    with pytest.raises(ValueError, match="treedef"):
        restore_checkpoint(f, {"a": jnp.zeros((3,)), "z": jnp.ones((3,))})
    with pytest.raises(ValueError, match="treedef"):
        restore_checkpoint(f, (jnp.zeros((3,)), jnp.ones((3,))))


def test_save_publishes_atomically_no_stray_tmp(tmp_path):
    """Both the .npz and the .treedef.txt go through tmp + os.replace: after
    a successful save the directory holds exactly the two published files."""
    save_checkpoint(str(tmp_path / "c"), {"a": jnp.zeros((2,))})
    names = sorted(os.listdir(tmp_path))
    assert names == ["c.npz", "c.treedef.txt"]
    assert not any(n.endswith(".tmp") for n in names)


# ---------------------------------------------------------------------------
# CheckpointManager
# ---------------------------------------------------------------------------


def _state(x=0.0):
    return {"w": jnp.full((4,), x, jnp.float32), "t": jnp.asarray(0, jnp.int32)}


def test_manager_save_latest_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    assert mgr.latest() is None
    assert mgr.read_manifest() is None
    mgr.save(_state(1.0), step=2)
    mgr.save(_state(2.0), step=4)
    assert mgr.latest() == 4
    manifest = mgr.read_manifest()
    assert manifest["step"] == 4
    assert manifest["steps"] == [2, 4]
    assert manifest["format"] == 1
    assert "jax" in manifest["versions"] and "numpy" in manifest["versions"]
    got = mgr.restore(_state())
    np.testing.assert_array_equal(np.asarray(got["w"]), np.full((4,), 2.0))
    # explicit older step is still reachable while retained
    got2 = mgr.restore(_state(), step=2)
    np.testing.assert_array_equal(np.asarray(got2["w"]), np.full((4,), 1.0))


def test_manager_restore_or_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    template = _state(7.0)
    state, step = mgr.restore_or_init(template)
    assert step == 0 and state is template  # fresh: the template itself
    mgr.save(_state(3.0), step=5)
    state, step = mgr.restore_or_init(_state())
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state["w"]), np.full((4,), 3.0))


def test_manager_retention_keep_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep_last=2)
    for step in (1, 2, 3, 4):
        mgr.save(_state(float(step)), step=step)
    manifest = mgr.read_manifest()
    assert manifest["steps"] == [3, 4]
    files = sorted(os.listdir(tmp_path / "ck"))
    assert files == [
        "manifest.json",
        "state_00000003.npz", "state_00000003.treedef.txt",
        "state_00000004.npz", "state_00000004.treedef.txt",
    ]
    assert mgr.latest() == 4


def test_manager_config_fingerprint_guard(tmp_path):
    fp_a = config_fingerprint({"rounds": 10, "seed": 0})
    fp_b = config_fingerprint({"rounds": 20, "seed": 0})
    assert fp_a != fp_b
    # stable across key ordering
    assert fp_a == config_fingerprint({"seed": 0, "rounds": 10})
    CheckpointManager(str(tmp_path / "ck"), fingerprint=fp_a).save(_state(), step=1)
    with pytest.raises(ValueError, match="fingerprint"):
        CheckpointManager(str(tmp_path / "ck"), fingerprint=fp_b).restore(_state())
    # same fingerprint resumes fine
    CheckpointManager(str(tmp_path / "ck"), fingerprint=fp_a).restore(_state())


def test_manager_treedef_hash_guard(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    mgr.save(_state(), step=1)
    with pytest.raises(ValueError, match="treedef"):
        mgr.restore({"w": jnp.zeros((4,), jnp.float32), "u": jnp.asarray(0, jnp.int32)})


def test_manager_manifest_is_commit_point(tmp_path):
    """A checkpoint file without a manifest entry is unreachable (the torn-
    write story): drop a stray step file next to a committed one and latest()
    still reports only what the manifest committed."""
    mgr = CheckpointManager(str(tmp_path / "ck"))
    mgr.save(_state(1.0), step=2)
    # stray uncommitted files (as if the process died before the manifest write)
    save_checkpoint(mgr.checkpoint_path(9), _state(9.0))
    assert mgr.latest() == 2
    got, step = mgr.restore_or_init(_state())
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["w"]), np.full((4,), 1.0))
    # and a manifest pointing at a deleted file falls back to an older step
    mgr.save(_state(3.0), step=4)
    os.remove(mgr.checkpoint_path(4))
    assert mgr.latest() == 2


# ---------------------------------------------------------------------------
# wait_for_next: the blocking read side of the train-to-serve hand-off
# ---------------------------------------------------------------------------


def test_wait_for_next_returns_newly_committed_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    assert mgr.wait_for_next(0, timeout=0.05) is None  # nothing ever committed
    mgr.save(_state(1.0), step=2)
    assert mgr.wait_for_next(0, timeout=0.05) == 2
    # already-seen steps don't satisfy the wait
    assert mgr.wait_for_next(2, timeout=0.05) is None
    # timeout=0 is the non-blocking one-shot check
    assert mgr.wait_for_next(0, timeout=0.0) == 2
    assert mgr.wait_for_next(2, timeout=0.0) is None


def test_wait_for_next_against_concurrent_writer(tmp_path):
    """A reader polling ``wait_for_next`` while a writer thread publishes
    boundaries must see a strictly increasing step sequence and restore
    complete state at EVERY step it observes — the atomic-manifest commit
    point means a torn step is never visible, only a possibly-stale one."""
    import threading

    path = str(tmp_path / "ck")
    steps = [2, 4, 6, 8, 10]
    writer_mgr = CheckpointManager(path, keep_last=len(steps))

    def writer():
        import time

        for s in steps:
            writer_mgr.save(_state(float(s)), step=s)
            time.sleep(0.02)

    reader_mgr = CheckpointManager(path)
    t = threading.Thread(target=writer)
    t.start()
    seen = []
    after = 0
    while after < steps[-1]:
        step = reader_mgr.wait_for_next(after, timeout=5.0, poll_interval=0.005)
        assert step is not None, f"writer stalled after {seen}"
        assert step > after  # monotone: never a stale or repeated boundary
        got = reader_mgr.restore(_state(), step=step)
        np.testing.assert_array_equal(  # never torn: value matches its step
            np.asarray(got["w"]), np.full((4,), float(step))
        )
        seen.append(step)
        after = step
    t.join()
    assert seen[-1] == steps[-1]
    assert set(seen) <= set(steps)


# ---------------------------------------------------------------------------
# Sampler serializable-state contract: full registry round-trip sweep
# ---------------------------------------------------------------------------


def _advance(s, state, key, rounds, n):
    """Drive `rounds` rounds of the sampler life cycle, returning the state
    trajectory's probabilities so the test compares behaviour, not just leaves."""
    fb_full = jax.random.uniform(jax.random.PRNGKey(17), (n,), minval=0.1, maxval=1.0)
    probs = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        p = s.probabilities(state)
        draw = s.sample_from(p, sub)
        state = s.update(state, draw, fb_full * draw.mask)
        probs.append(np.asarray(p))
    return state, key, probs


@pytest.mark.parametrize("name", sorted(samplers._REGISTRY))
def test_sampler_state_survives_checkpoint_round_trip(name, tmp_path):
    """Every registered sampler's state obeys the serializable-state contract:
    3 rounds -> save -> restore into a FRESH ``init()`` template -> 5 more
    rounds must be bitwise-equal (probabilities and every state leaf) to the
    same 8 rounds without the round trip."""
    n, k = 16, 4
    s = samplers.make_sampler(name, n=n, budget=k)
    key = jax.random.PRNGKey(0)

    state, key_mid, _ = _advance(s, s.init(), key, 3, n)
    samplers.assert_serializable_state(state)

    mgr = CheckpointManager(str(tmp_path / name))
    mgr.save(state, step=3)
    restored, step = mgr.restore_or_init(s.init())  # fresh-template restore
    assert step == 3

    cont, _, probs_cont = _advance(s, restored, key_mid, 5, n)
    ref, _, probs_ref = _advance(s, state, key_mid, 5, n)
    np.testing.assert_array_equal(np.stack(probs_cont), np.stack(probs_ref))
    for a, b in zip(jax.tree_util.tree_leaves(cont), jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_assert_serializable_state_rejects_python_scalars():
    samplers.assert_serializable_state(
        samplers.SamplerState(
            stats=jnp.zeros(3), aux=jnp.zeros(3), t=jnp.asarray(0, jnp.int32)
        )
    )
    with pytest.raises(TypeError, match="not an array"):
        samplers.assert_serializable_state(
            samplers.SamplerState(stats=jnp.zeros(3), aux=jnp.zeros(3), t=0)
        )
    with pytest.raises(ValueError, match="no array leaves"):
        samplers.assert_serializable_state({})
