"""The segmented compiled horizon must be a pure reshaping of the monolithic
scan: for ANY ``ckpt_every`` the per-round bodies see the same carries, keys,
and round indices, so params, sampler state, and ``History`` are bitwise
identical — and a segment boundary is a preemption-safe escape hatch where the
canonical ``TrainState`` round-trips through a ``CheckpointManager`` and a
restarted process continues the run exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import make_sampler
from repro.data import synthetic_classification, synthetic_tokens
from repro.fed import (
    FedConfig,
    build_segment_runner,
    logistic_regression,
    run_federated,
    run_segmented,
)


@pytest.fixture(scope="module")
def tiny_ds():
    return synthetic_classification(n_clients=12, total=600, seed=7)


def _histories_equal(a, b):
    assert a.train_loss == b.train_loss
    assert a.cohort_size == b.cohort_size
    assert a.cohort_dropped == b.cohort_dropped
    assert a.estimator_sq_error == b.estimator_sq_error
    assert a.test_accuracy == b.test_accuracy
    assert a.rounds == b.rounds
    if a.regret is not None and a.regret.costs:
        assert a.regret.costs == b.regret.costs
        assert a.regret.opt_costs == b.regret.opt_costs
        if a.regret.score_history:
            np.testing.assert_array_equal(
                np.stack(a.regret.score_history), np.stack(b.regret.score_history)
            )
    for x, y in zip(
        jax.tree_util.tree_leaves(a.final_params),
        jax.tree_util.tree_leaves(b.final_params),
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _run(ds, name, **cfg_kw):
    cfg = FedConfig(
        rounds=10, budget=4, local_steps=2, batch_size=16, local_lr=0.05, seed=11,
        **cfg_kw,
    )
    sampler = make_sampler(
        name, n=ds.n_clients, budget=cfg.budget,
        **({"horizon": cfg.rounds} if name in ("kvib", "vrb") else {}),
    )
    ev = ds.batch_all_clients(jax.random.PRNGKey(99), 4)
    ev = (ev[0].reshape(-1, ev[0].shape[-1]), ev[1].reshape(-1))
    return run_federated(logistic_regression(), ds, sampler, cfg, eval_data=ev)


@pytest.mark.parametrize("ckpt_every", [1, 7, 10])
def test_segmented_bitwise_identical_to_monolithic(tiny_ds, ckpt_every):
    """Acceptance: ckpt_every in {1, 7, T} reproduces the monolithic scan's
    params, sampler-driven draws, metric buffers, and eval schedule exactly
    (T=10: segmentations of 10x1, 7+3, and the degenerate single segment)."""
    h_mono = _run(tiny_ds, "kvib", ckpt_every=0)
    h_seg = _run(tiny_ds, "kvib", ckpt_every=ckpt_every)
    _histories_equal(h_seg, h_mono)


@pytest.mark.parametrize("name", ["vrb", "uniform_rsp"])
def test_segmented_identity_rsp_procedures(tiny_ds, name):
    """The identity holds across sampling procedures (RSP draw paths have
    their own key-consumption pattern inside the body)."""
    _histories_equal(
        _run(tiny_ds, name, ckpt_every=3), _run(tiny_ds, name, ckpt_every=0)
    )


def test_segmented_identity_deployable_cohort(tiny_ds):
    """Deployable mode (cohort-only training, C-width aggregation, overflow
    drops) is segmentation-invariant too — including the dropped counters."""
    kw = dict(oracle_metrics=False, cohort=4)
    _histories_equal(
        _run(tiny_ds, "kvib", ckpt_every=3, **kw),
        _run(tiny_ds, "kvib", ckpt_every=0, **kw),
    )


def test_segment_runner_state_advances(tiny_ds):
    """The TrainState carry advances round/key and stitches metric buffers
    in place: after k rounds, exactly the first k buffer slots are written."""
    cfg = FedConfig(rounds=6, budget=4, local_steps=1, batch_size=16, seed=3)
    sampler = make_sampler("kvib", n=tiny_ds.n_clients, budget=4, horizon=6)
    segment, state0 = build_segment_runner(
        logistic_regression(), tiny_ds, sampler, cfg
    )
    assert int(state0.round) == 0
    st = segment(state0, 2)
    assert int(st.round) == 2
    assert not np.array_equal(np.asarray(st.key), np.asarray(state0.key))
    loss = np.asarray(st.metrics["train_loss"])
    assert loss.shape == (6,)
    assert np.all(loss[:2] != 0.0) and np.all(loss[2:] == 0.0)
    st = segment(st, 4)
    assert int(st.round) == 6
    assert np.all(np.asarray(st.metrics["train_loss"]) != 0.0)


def test_preempt_checkpoint_resume_bitwise(tiny_ds, tmp_path):
    """Preemption simulation, in-process: run 2 of 5 segments with a manager,
    'restart' by restoring the latest committed step into a fresh template,
    finish the horizon, and compare the FULL TrainState — params, sampler
    state, every metric buffer slot (including pre-preemption rounds), round
    index, and RNG key — bitwise against an uninterrupted run."""
    cfg = FedConfig(rounds=10, budget=4, local_steps=1, batch_size=16, seed=5,
                    ckpt_every=2)
    task = logistic_regression()

    def runner():
        sampler = make_sampler("kvib", n=tiny_ds.n_clients, budget=4, horizon=10)
        return build_segment_runner(task, tiny_ds, sampler, cfg)

    segment, state0 = runner()
    full = run_segmented(state0, cfg.rounds, segment, ckpt_every=cfg.ckpt_every)

    mgr = CheckpointManager(str(tmp_path / "ck"), keep_last=2)
    segment_b, state0_b = runner()
    preempted = run_segmented(
        state0_b, cfg.rounds, segment_b, ckpt_every=cfg.ckpt_every,
        manager=mgr, max_segments=2,
    )
    assert int(preempted.round) == 4
    assert mgr.latest() == 4

    # "process restart": fresh template, fresh jitted segment, restore.
    segment_c, template = runner()
    restored, step = mgr.restore_or_init(template)
    assert step == 4 and int(restored.round) == 4
    resumed = run_segmented(
        restored, cfg.rounds, segment_c, ckpt_every=cfg.ckpt_every, manager=mgr
    )
    assert int(resumed.round) == cfg.rounds
    for a, b in zip(
        jax.tree_util.tree_leaves(resumed), jax.tree_util.tree_leaves(full)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_federated_resumes_from_manager(tiny_ds, tmp_path):
    """run_federated(ckpt_manager=...) end to end: a run preempted at the
    driver level and re-invoked with the same manager yields the identical
    History as a never-interrupted run — including pre-preemption rounds."""
    cfg = FedConfig(rounds=8, budget=4, local_steps=1, batch_size=16, seed=5,
                    ckpt_every=3)
    task = logistic_regression()

    def sampler():
        return make_sampler("kvib", n=tiny_ds.n_clients, budget=4, horizon=8)

    h_full = run_federated(task, tiny_ds, sampler(), cfg)

    # Preempt: run only the first segment (3 rounds) with a manager.
    mgr = CheckpointManager(str(tmp_path / "ck"))
    segment, state0 = build_segment_runner(task, tiny_ds, sampler(), cfg)
    run_segmented(state0, cfg.rounds, segment, ckpt_every=cfg.ckpt_every,
                  manager=mgr, max_segments=1)
    assert mgr.latest() == 3

    h_resumed = run_federated(task, tiny_ds, sampler(), cfg, ckpt_manager=mgr)
    _histories_equal(h_resumed, h_full)
    assert mgr.latest() == 8


def test_run_federated_rejects_manager_without_segments(tiny_ds, tmp_path):
    """A manager with ckpt_every=0 would publish nothing before the final
    round — a silent no-protection configuration; it must raise instead."""
    cfg = FedConfig(rounds=4, budget=2, local_steps=1, batch_size=8)
    sampler = make_sampler("uniform_isp", n=tiny_ds.n_clients, budget=2)
    with pytest.raises(ValueError, match="ckpt_every"):
        run_federated(
            logistic_regression(), tiny_ds, sampler, cfg,
            ckpt_manager=CheckpointManager(str(tmp_path / "ck")),
        )


def test_fed_scan_segment_matches_monolithic():
    """fed/round.py: the segment-shaped pod-scale scan reproduces the
    monolithic build_fed_scan bitwise for ckpt_every in {1, 2, T} — identical
    key chain (in-trace derivation == host-side stacking), identical round
    bodies, identical metric values."""
    from repro.configs import get_config
    from repro.fed.round import RoundSpec, build_fed_scan, build_fed_scan_segment

    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=64, d_ff=128, vocab=128)
    ds = synthetic_tokens(n_clients=8, seq_len=16, vocab=cfg.vocab, total_seqs=256, seed=3)
    spec = RoundSpec(cohort=3, local_steps=2, local_lr=0.05, local_batch=2)
    sampler = make_sampler("kvib", n=ds.n_clients, budget=2, horizon=4)
    rounds = 4

    from repro.models import transformer

    key = jax.random.PRNGKey(5)
    params0 = transformer.init_params(cfg, key)

    # Monolithic reference: host-derived key pairs, one scan.
    k = key
    pairs = []
    for _ in range(rounds):
        k, k_draw, k_data = jax.random.split(k, 3)
        pairs.append(jnp.stack([k_draw, k_data]))
    run = build_fed_scan(cfg, spec, sampler, ds)
    p_mono, s_mono, m_mono = run(
        jax.tree_util.tree_map(jnp.copy, params0), sampler.init(), jnp.stack(pairs)
    )

    segment, make_state = build_fed_scan_segment(cfg, spec, sampler, ds)
    for every in (1, 2, rounds):
        state = make_state(
            jax.tree_util.tree_map(jnp.copy, params0), sampler.init(), key, rounds
        )
        state = run_segmented(state, rounds, segment, ckpt_every=every)
        assert int(state.round) == rounds
        for name, ref in m_mono.items():
            np.testing.assert_array_equal(
                np.asarray(state.metrics[name]), np.asarray(ref), err_msg=name
            )
        for a, b in zip(
            jax.tree_util.tree_leaves(state.params), jax.tree_util.tree_leaves(p_mono)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
            jax.tree_util.tree_leaves(state.sampler), jax.tree_util.tree_leaves(s_mono)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
