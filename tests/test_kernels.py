"""Pallas-kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_weighted_agg import (
    fused_cohort_agg_and_error,
    fused_multi_weighted_agg,
    fused_weighted_agg,
)
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

F32, BF16 = jnp.float32, jnp.bfloat16


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == BF16 else dict(rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize(
    "h,s,hd,bq,bk",
    [
        (2, 256, 64, 128, 128),
        (1, 512, 128, 128, 256),
        (3, 128, 32, 64, 64),
        (1, 256, 256, 128, 128),
    ],
)
@pytest.mark.parametrize("mode", ["causal", "window", "full", "softcap"])
def test_flash_attention_sweep(dtype, h, s, hd, bq, bk, mode):
    key = jax.random.PRNGKey(hash((h, s, hd)) % 2**31)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (h, s, hd), dtype)
    k = jax.random.normal(ks[1], (h, s, hd), dtype)
    v = jax.random.normal(ks[2], (h, s, hd), dtype)
    kw = {
        "causal": dict(causal=True),
        "window": dict(causal=True, window=96),
        "full": dict(causal=False),
        "softcap": dict(causal=True, softcap=30.0),
    }[mode]
    got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True, **kw)
    want = ref.mha_reference(q, k, v, **kw)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("bh,s,hd,n,chunk", [(2, 256, 64, 32, 128), (1, 512, 32, 64, 64), (4, 128, 128, 16, 128)])
def test_ssd_scan_sweep(dtype, bh, s, hd, n, chunk):
    key = jax.random.PRNGKey(hash((bh, s, hd, n)) % 2**31)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bh, s, hd), dtype)
    # realistic decays: small negative
    da = -jax.nn.softplus(jax.random.normal(ks[1], (bh, s))) * 0.1
    b = jax.random.normal(ks[2], (bh, s, n), dtype) * 0.5
    c = jax.random.normal(ks[3], (bh, s, n), dtype) * 0.5
    got = ssd_scan(x, da.astype(dtype), b, c, chunk=chunk, interpret=True)
    want, _ = ref.ssd_reference(x, da, b, c)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=3e-2 if dtype == BF16 else 1e-3,
        atol=3e-2 if dtype == BF16 else 1e-3,
    )


def test_ssd_kernel_matches_model_chunked_path():
    """The Pallas kernel and the model's jnp chunked path agree (same math,
    two implementations, one oracle)."""
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(0)
    bsz, s, h, hd, n = 2, 256, 3, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, hd))
    dt = jax.random.normal(ks[1], (bsz, s, h)) * 0.1
    a_log = jax.random.normal(ks[2], (h,)) * 0.1
    b = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, n)) * 0.5
    d_skip = jnp.zeros((h,))

    y_model = ssd_chunked(x, dt, a_log, b, c, d_skip, chunk=128)

    # kernel consumes per-head flattened (BH, S, ...) with explicit decays
    dtf = jax.nn.softplus(dt)
    da = dtf * (-jnp.exp(a_log))[None, None, :]
    xa = x * dtf[..., None]
    xa_f = jnp.moveaxis(xa, 2, 1).reshape(bsz * h, s, hd)
    da_f = jnp.moveaxis(da, 2, 1).reshape(bsz * h, s)
    b_f = jnp.repeat(b[:, None], h, 1).reshape(bsz * h, s, n)
    c_f = jnp.repeat(c[:, None], h, 1).reshape(bsz * h, s, n)
    y_k = ssd_scan(xa_f, da_f, b_f, c_f, chunk=128, interpret=True)
    y_k = jnp.moveaxis(y_k.reshape(bsz, h, s, hd), 1, 2)
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_model), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("c,d,bd", [(8, 4096, 1024), (16, 2048, 2048), (3, 8192, 512)])
def test_fused_weighted_agg_sweep(dtype, c, d, bd):
    key = jax.random.PRNGKey(c * d % 2**31)
    g = jax.random.normal(key, (c, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (c,), jnp.float32)
    d_got, sq_got = fused_weighted_agg(g, w, block_d=bd, interpret=True)
    d_want, sq_want = ref.weighted_agg_reference(g, w)
    tol = dict(rtol=2e-2, atol=1e-2) if dtype == BF16 else dict(rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), **tol)
    np.testing.assert_allclose(np.asarray(sq_got), np.asarray(sq_want), **tol)


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("m,c,d,bd", [(2, 8, 4096, 1024), (3, 16, 2048, 2048)])
def test_fused_multi_weighted_agg_sweep(dtype, m, c, d, bd):
    """M weighted aggregates in one pass == M separate matvec reductions."""
    g = jax.random.normal(jax.random.PRNGKey(0), (c, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (m, c), jnp.float32)
    got = fused_multi_weighted_agg(g, w, block_d=bd, interpret=True)
    want = w @ g.astype(jnp.float32)
    tol = dict(rtol=2e-2, atol=1e-2) if dtype == BF16 else dict(rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("c,d,bd", [(8, 4096, 1024), (20, 2048, 2048), (3, 1024, 256)])
def test_fused_cohort_agg_and_error_sweep(dtype, c, d, bd):
    """Cohort-width fused kernel == unfused two-row contraction + host square:
    d = sum_c w_c g_c and err_sq = ||sum_c (w_c - lam_c) g_c||^2, with the
    error row never leaving the kernel at (D,) width."""
    g = jax.random.normal(jax.random.PRNGKey(0), (c, d), dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (c,), jnp.float32)
    lam_c = jax.random.uniform(jax.random.PRNGKey(2), (c,), jnp.float32) * 0.1
    # padding-slot contract: zero weight AND zero lam -> slot is inert
    w = w.at[-1].set(0.0)
    lam_c = lam_c.at[-1].set(0.0)
    d_got, sq_got = fused_cohort_agg_and_error(g, w, lam_c, block_d=bd, interpret=True)
    gf = g.astype(jnp.float32)
    d_want = w @ gf
    sq_want = jnp.sum(((w - lam_c) @ gf) ** 2)
    tol = dict(rtol=2e-2, atol=1e-2) if dtype == BF16 else dict(rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want), **tol)
    np.testing.assert_allclose(float(sq_got), float(sq_want), rtol=1e-2 if dtype == BF16 else 1e-4)


def test_aggregate_and_error_cohort_matches_scatter_path():
    """estimator.aggregate_and_error_cohort over (C, ...) pytrees equals
    estimator.aggregate_and_error over the zero-scattered (N, ...) pytrees —
    the defining equivalence of the cohort-width contract."""
    from repro.core import estimator
    from repro.fed import cohort

    n, c = 24, 5
    key = jax.random.PRNGKey(6)
    deltas_c = {
        "w": jax.random.normal(key, (c, 30, 10)),
        "b": jax.random.normal(jax.random.PRNGKey(7), (c, 10)),
    }
    lam = jax.random.dirichlet(jax.random.PRNGKey(8), jnp.ones(n))
    sel = cohort.CohortSelection(
        ids=jnp.asarray([3, 17, 9, 1, 0], jnp.int32),
        weights=jnp.asarray([1.3, 0.4, 2.0, 0.0, 0.0]),
        valid=jnp.asarray([True, True, True, False, False]),
        n_included=jnp.asarray(3, jnp.int32),
        n_dropped=jnp.asarray(0, jnp.int32),
    )
    lam_c = jnp.where(sel.valid, lam[sel.ids], 0.0)
    d_cw, sq_cw = estimator.aggregate_and_error_cohort(deltas_c, sel.weights, lam_c)

    deltas_n = cohort.scatter_cohort(deltas_c, sel, n)
    w_n = cohort.scatter_cohort(sel.weights, sel, n)
    # the scatter path diagnoses against lam restricted to the cohort support
    lam_n = cohort.scatter_cohort(lam_c, sel, n)
    d_sc, sq_sc = estimator.aggregate_and_error(deltas_n, w_n, lam_n)
    for k in ("w", "b"):
        np.testing.assert_allclose(
            np.asarray(d_cw[k]), np.asarray(d_sc[k]), rtol=1e-5, atol=1e-5
        )
    np.testing.assert_allclose(float(sq_cw), float(sq_sc), rtol=1e-5)


@pytest.mark.parametrize("dtype", [F32, BF16])
@pytest.mark.parametrize("r,d,br", [(256, 512, 128), (128, 960, 128), (64, 128, 64)])
def test_rmsnorm_sweep(dtype, r, d, br):
    x = jax.random.normal(jax.random.PRNGKey(0), (r, d), dtype)
    scale = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32) * 0.1
    got = rmsnorm(x, scale, block_rows=br, interpret=True)
    want = ref.rmsnorm_reference(x, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == BF16 else 1e-5,
        atol=2e-2 if dtype == BF16 else 1e-5,
    )


def test_aggregate_cohort_updates_pytree():
    """End-to-end: fused kernel over a stacked update pytree matches the
    estimator-module reference path."""
    from repro.core import estimator
    from repro.kernels import ops

    key = jax.random.PRNGKey(3)
    c = 6
    deltas = {
        "w": jax.random.normal(key, (c, 33, 17)),
        "b": jax.random.normal(jax.random.PRNGKey(4), (c, 129)),
    }
    w = jax.random.uniform(jax.random.PRNGKey(5), (c,))
    got_tree, sq = ops.aggregate_cohort_updates(deltas, w, block_d=512)
    want_tree = estimator.aggregate_stacked(deltas, w)
    for ka in ("w", "b"):
        np.testing.assert_allclose(
            np.asarray(got_tree[ka]), np.asarray(want_tree[ka]), rtol=1e-5, atol=1e-5
        )
    # norms match the fed client util
    from repro.fed.client import update_norm

    for i in range(c):
        one = jax.tree_util.tree_map(lambda x: x[i], deltas)
        np.testing.assert_allclose(
            float(jnp.sqrt(sq[i])), float(update_norm(one)), rtol=1e-5
        )
