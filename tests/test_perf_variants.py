"""Perf-variant implementations must match their reference paths exactly
(EXPERIMENTS.md section Perf: every optimization keeps the math)."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer, xlstm


def test_mlstm_chunked_matches_cell():
    B, S, H, hd = 2, 256, 3, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2.0
    fg = jax.random.normal(ks[4], (B, S, H)) * 2.0 + 2.0
    h_ref = xlstm._mlstm_cell(q, k, v, ig, fg)
    for chunk in (32, 128):
        h_chk, _ = xlstm.mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(h_chk, np.float32), np.asarray(h_ref, np.float32),
            atol=2e-3, rtol=2e-3,
        )


def test_mlstm_chunked_final_state_matches_decode_chain():
    """Chunked-prefill state must continue identically under decode steps."""
    B, S, H, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    _, (c_chk, n_chk, m_chk) = xlstm.mlstm_chunked(q, k, v, ig, fg, chunk=16)

    # sequential replay for the reference state
    scale = hd**-0.5
    c = jnp.zeros((B, H, hd, hd)); n = jnp.zeros((B, H, hd)); m = jnp.full((B, H), -jnp.inf)
    lf_all = jax.nn.log_sigmoid(fg)
    for t in range(S):
        m_new = jnp.maximum(lf_all[:, t] + m, ig[:, t])
        f_s = jnp.exp(lf_all[:, t] + m - m_new)[..., None]
        i_s = jnp.exp(ig[:, t] - m_new)[..., None]
        kt = k[:, t] * scale
        c = c * f_s[..., None] + i_s[..., None] * (v[:, t][..., :, None] * kt[..., None, :])
        n = n * f_s + i_s * kt
        m = m_new
    np.testing.assert_allclose(np.asarray(c_chk), np.asarray(c), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(n_chk), np.asarray(n), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(m_chk), np.asarray(m), atol=1e-4, rtol=1e-4)


def test_chunked_attention_matches_einsum():
    cfg = get_config("smollm-360m").reduced(n_layers=2)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 1024), 0, cfg.vocab)
    l1, _ = transformer.forward(params, cfg, tok)
    cfg2 = dataclasses.replace(cfg, attn_impl="chunked")
    l2, _ = transformer.forward(params, cfg2, tok)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), atol=1e-4, rtol=1e-3
    )


_A2A_PROBE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer, moe as moe_mod
    from repro.models import sharding as msharding

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = get_config("qwen3-moe-235b-a22b").reduced(
        n_layers=1, d_model=64, n_experts=4, top_k=2, moe_d_ff=64,
        vocab=128, capacity_factor=8.0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    moe_params = jax.tree_util.tree_map(lambda x: x[0], params["stacks"][0])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)
    y_dense, _ = moe_mod.moe_ffn(moe_params, cfg, x)
    cfg_a = dataclasses.replace(cfg, moe_impl="a2a")
    with msharding.use_rules(mesh, dict(msharding.DEFAULT_RULES)):
        y_a2a, _ = jax.jit(lambda p, x: moe_mod.moe_ffn(p, cfg_a, x))(moe_params, x)
    err = float(jnp.max(jnp.abs(y_dense - y_a2a)))
    print("RESULT", json.dumps({"err": err}))
    """
)


@pytest.mark.slow  # fresh-interpreter probe + multi-device MoE compile (~8 min)
def test_moe_a2a_matches_dense_subprocess():
    import json

    proc = subprocess.run(
        [sys.executable, "-c", _A2A_PROBE],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            err = json.loads(line.split(" ", 1)[1])["err"]
            assert err < 1e-3, err
            return
    raise AssertionError(proc.stdout)


def test_xlstm_forward_chunked_config():
    cfg = get_config("xlstm-125m").reduced()
    cfg2 = dataclasses.replace(cfg, mlstm_impl="chunked")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    l1, _ = transformer.forward(params, cfg, tok)
    l2, _ = transformer.forward(params, cfg2, tok)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), atol=5e-3, rtol=5e-3
    )
