"""Million-client sampler sharding: the (N,)-axis sharded solve/draw/update
must be the SAME math as the single-device reference.

Contract under test (core/solver.py docstring):

* one shard (S=1): the sharded water-filling solve — geometric bracket +
  exact Lemma B.8 snap on shard-local sorted prefixes — is BITWISE equal to
  ``_isp_solve`` for every sampler in the registry, on both bracket
  implementations (lax.scan bisection and the Pallas level-ladder kernel);
* S >= 2 shards: equal up to psum reassociation, |diff| <= 1e-6 (documented
  eps), exercised on a forced 2-device CPU mesh with prime N (the +inf
  padding path);
* host-path input validation raises on impossible budgets/floors and
  non-finite/negative scores instead of silently clipping;
* the (T, N) score-history buffer is size-guarded and its chunked
  host-offload ring reproduces the full-horizon buffer exactly;
* the sharded segment runner still compiles exactly once (placement
  normalization), and its round body passes the per-shard width audit;
* a checkpoint written under one mesh shape restores and finishes under a
  different one (arrays round-trip through host numpy; the restoring
  process re-lays them out per its own ShardSpec).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_sampler, solver
from repro.core.samplers import sampler_names
from repro.kernels.ref import waterfill_stats_reference
from repro.kernels.sharded_waterfill import waterfill_level_stats
from repro.launch.mesh import ShardSpec

SUBPROC_ENV = {
    "PYTHONPATH": "src",
    "PATH": "/usr/bin:/bin",
    "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
}


# ---------------------------------------------------------------------------
# Host-path input validation (satellite: solver.py guard rails)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "scores,budget,p_min,match",
    [
        (np.ones(8, np.float32), 9, 0.0, "budget"),
        (np.ones(8, np.float32), 0, 0.0, "budget"),
        (np.ones(8, np.float32), 2, 0.5, "p_min"),
        (np.array([1.0, np.nan, 1.0], np.float32), 2, 0.0, "finite"),
        (np.array([1.0, np.inf, 1.0], np.float32), 2, 0.0, "finite"),
        (np.array([1.0, -0.5, 1.0], np.float32), 2, 0.0, "negative"),
    ],
)
def test_solver_rejects_invalid_host_inputs(scores, budget, p_min, match):
    with pytest.raises(ValueError, match=match):
        solver.isp_probabilities(jnp.asarray(scores), budget, p_min)


def test_solver_accepts_zero_scores():
    """All-zero scores are legal (cold-start feedback) — no raise."""
    p = solver.isp_probabilities(jnp.zeros(8, jnp.float32), 3)
    assert np.all(np.isfinite(np.asarray(p)))


# ---------------------------------------------------------------------------
# Pallas level-stats kernel vs order-independent reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,l", [(7, 3), (128, 5), (300, 17)])
def test_waterfill_kernel_matches_reference(m, l):
    rng = np.random.default_rng(m * 1000 + l)
    scores = jnp.asarray(rng.gamma(2.0, 1.0, size=m).astype(np.float32))
    levels = jnp.asarray(np.sort(rng.gamma(2.0, 1.0, size=l)).astype(np.float32))
    floors = levels * jnp.float32(0.05)
    got = waterfill_level_stats(scores, levels, floors, interpret=True)
    want = waterfill_stats_reference(scores, levels, floors)
    # counts are exact small integers in f32; the mid-sum may differ from the
    # order-independent reference by summation-order eps (it only brackets —
    # the solve's exact snap is summation-order independent)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(
        np.asarray(got[2]), np.asarray(want[2]), rtol=1e-6
    )


def test_waterfill_kernel_inf_padding_never_counts():
    """+inf-padded entries (the N % S != 0 remainder) sort above every finite
    level: they contribute to no count and no mid-sum."""
    scores = jnp.asarray([1.0, 2.0, np.inf, np.inf], jnp.float32)
    levels = jnp.asarray([1.5, 100.0], jnp.float32)
    floors = jnp.asarray([0.1, 5.0], jnp.float32)
    n_below, n_floor, mid = waterfill_level_stats(
        scores, levels, floors, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(n_below), [1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(n_floor), [0.0, 2.0])
    np.testing.assert_array_equal(np.asarray(mid), [1.0, 0.0])


# ---------------------------------------------------------------------------
# S=1 bitwise equality: sharded solve == single-device solve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sharded_solve_bitwise_equal_single_shard(use_kernel):
    shard = ShardSpec()  # one "data" shard
    rng = np.random.default_rng(42)
    for seed in range(8):
        n = int(rng.integers(5, 60))
        budget = int(rng.integers(1, n))
        p_min = float(rng.uniform(0.0, 0.9)) * budget / n
        a = jnp.asarray(rng.gamma(2.0, 1.0, size=n).astype(np.float32))
        ref = solver.isp_probabilities(a, budget, p_min)
        got = solver.isp_probabilities(
            a, budget, p_min, shard=shard, use_kernel=use_kernel
        )
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(got),
            err_msg=f"seed={seed} n={n} budget={budget} p_min={p_min} "
            f"use_kernel={use_kernel}",
        )


def test_sharded_solve_degenerate_budget_full_participation():
    a = jnp.asarray([0.0, 1.0, 2.0], jnp.float32)
    got = solver.isp_probabilities(a, 3, 0.0, shard=ShardSpec())
    np.testing.assert_array_equal(np.asarray(got), np.ones(3, np.float32))


# ---------------------------------------------------------------------------
# Registry sweep: every sampler, sharded state == unsharded state (S=1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sampler_names())
def test_registry_sampler_sharded_bitwise_single_shard(name):
    n, budget, rounds = 13, 4, 3
    kw = {"horizon": rounds} if name in ("kvib", "vrb") else {}
    plain = make_sampler(name, n=n, budget=budget, **kw)
    sharded = dataclasses.replace(plain, shard=ShardSpec())

    def roll(sampler):
        @jax.jit
        def step(state, key):
            p = sampler.probabilities(state)
            draw = sampler.sample_from(p, key)
            fb = draw.mask * (1.0 + jnp.arange(n, dtype=jnp.float32))
            return sampler.update(state, draw, fb), p

        state = sampler.init()
        ps = []
        for t in range(rounds):
            state, p = step(state, jax.random.PRNGKey(100 + t))
            ps.append(np.asarray(p))
        return state, ps

    st0, ps0 = roll(plain)
    st1, ps1 = roll(sharded)
    for a, b in zip(ps0, ps1):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(
        jax.tree_util.tree_leaves(st0), jax.tree_util.tree_leaves(st1)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_abstract_state_carries_sharding_annotations():
    s = dataclasses.replace(
        make_sampler("kvib", n=13, budget=4, horizon=3), shard=ShardSpec()
    )
    leaves = jax.tree_util.tree_leaves(s.abstract_state())
    annotated = [
        leaf for leaf in leaves
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == 13
    ]
    assert annotated, "expected (N,)-leaves in the abstract state"
    for leaf in annotated:
        assert leaf.sharding is not None
        assert leaf.sharding.spec[0] == "data"


# ---------------------------------------------------------------------------
# Score history: size guard + host-offload ring equivalence
# ---------------------------------------------------------------------------


def _sim_pieces(n_clients=12, rounds=6):
    from repro.data import synthetic_classification
    from repro.fed import FedConfig, logistic_regression

    ds = synthetic_classification(n_clients=n_clients, total=50 * n_clients, seed=7)
    cfg = FedConfig(
        rounds=rounds, budget=4, local_steps=2, batch_size=16, local_lr=0.05,
        seed=11, compiled=True, ckpt_every=2,
    )
    return ds, cfg, logistic_regression()


def test_score_history_size_guard_raises():
    from repro.fed import run_federated

    ds, cfg, task = _sim_pieces()
    s = make_sampler("kvib", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds)
    tiny = dataclasses.replace(cfg, score_history_bytes_limit=8)
    with pytest.raises(ValueError, match="score_history_host_offload"):
        run_federated(task, ds, s, tiny)
    # offload lifts the guard: the device buffer is one segment, not (T, N)
    run_federated(
        task, ds, s,
        dataclasses.replace(tiny, score_history_host_offload=True),
    )


def test_score_history_offload_matches_full_buffer():
    from repro.fed import run_federated

    ds, cfg, task = _sim_pieces()
    s = dataclasses.replace(
        make_sampler("kvib", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds),
        shard=ShardSpec(),
    )
    h_full = run_federated(task, ds, s, cfg)
    h_ring = run_federated(
        task, ds, s, dataclasses.replace(cfg, score_history_host_offload=True)
    )
    assert h_full.train_loss == h_ring.train_loss
    np.testing.assert_array_equal(
        np.stack(h_full.regret.score_history),
        np.stack(h_ring.regret.score_history),
    )


def test_score_history_offload_requires_ckpt_every():
    from repro.fed import run_federated

    ds, cfg, task = _sim_pieces()
    s = make_sampler("kvib", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds)
    bad = dataclasses.replace(cfg, ckpt_every=0, score_history_host_offload=True)
    with pytest.raises(ValueError, match="ckpt_every"):
        run_federated(task, ds, s, bad)


# ---------------------------------------------------------------------------
# Compile-once with placement + per-shard width audit
# ---------------------------------------------------------------------------


def test_sharded_segment_runner_compiles_once():
    from repro.analysis import lint
    from repro.fed.server import build_segment_runner

    ds, cfg, task = _sim_pieces()
    s = dataclasses.replace(
        make_sampler("kvib", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds),
        shard=ShardSpec(),
    )
    segment, state = build_segment_runner(task, ds, s, cfg)
    violations = lint.audit_compile_once(segment, state, 2, n_segments=2)
    assert violations == [], "\n".join(f.render() for f in violations)


def test_replicated_clients_audit_clean_on_sharded_body():
    from repro.analysis.lint import audit_replicated_clients
    from repro.fed import server as fed_server

    ds, cfg, task = _sim_pieces(n_clients=13)
    cfg = dataclasses.replace(cfg, oracle_metrics=False)
    s = dataclasses.replace(
        make_sampler("kvib", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds),
        shard=ShardSpec(),
    )
    body, (carry, xs) = fed_server.round_body_for_lint(task, ds, s, cfg, None)
    closed = jax.make_jaxpr(body)(carry, xs)
    findings = audit_replicated_clients(closed, ds.n_clients)
    assert findings == [], "\n".join(f.render() for f in findings)
    # the ceiling is a real tripwire: at 0 the documented per-round vector
    # set itself trips it
    assert audit_replicated_clients(closed, ds.n_clients, max_unconstrained=0)


# ---------------------------------------------------------------------------
# 2-device mesh: prime-N eps + resume onto a different mesh shape
# ---------------------------------------------------------------------------


@pytest.mark.slow  # fresh interpreter: forced 2-device CPU mesh
def test_two_device_prime_n_solve_within_eps_subprocess():
    """S=2 with N=13 (prime, so the +inf padding path is live): the sharded
    solve may differ from the single-device solve only by psum reassociation
    — |diff| <= 1e-6 — and the budget constraint still holds exactly."""
    script = textwrap.dedent(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import solver
        from repro.launch.mesh import ShardSpec

        assert len(jax.devices()) == 2
        shard = ShardSpec(axes=(("data", 2),), axis="data")
        rng = np.random.default_rng(0)
        worst = 0.0
        for seed in range(10):
            a = jnp.asarray(rng.gamma(2.0, 1.0, size=13).astype(np.float32))
            ref = solver.isp_probabilities(a, 5, 0.05)
            got = solver.isp_probabilities(a, 5, 0.05, shard=shard)
            worst = max(worst, float(jnp.max(jnp.abs(ref - got))))
            assert abs(float(jnp.sum(got)) - 5.0) < 1e-4
        assert worst <= 1e-6, worst
        print("PRIME_N_OK", worst)
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600, env=SUBPROC_ENV,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PRIME_N_OK" in proc.stdout


@pytest.mark.slow  # two fresh interpreters: 2-device save, 1-device resume
def test_resume_onto_different_mesh_shape_subprocess(tmp_path):
    """A checkpoint written by a 2-device sharded run restores into a
    1-device process (different mesh shape) and finishes the horizon: the
    npz round-trips through host numpy and the restoring process lays the
    arrays out per its own ShardSpec.  Manifest records the WRITER's layout
    as provenance."""
    ckpt = str(tmp_path / "ck")
    spec_json = json.dumps(
        {
            "task": {
                "kind": "task",
                "name": "logreg",
                "dataset": "synthetic_classification",
                "dataset_kwargs": {"n_clients": 12, "total": 600, "seed": 7},
            },
            "sampler": {"name": "kvib", "kwargs": {"horizon": 4}},
            "federation": {
                "rounds": 4, "budget": 4, "local_steps": 2, "batch_size": 16,
                "local_lr": 0.05,
            },
            "execution": {
                "seed": 11, "compiled": True, "ckpt_every": 2,
                "sampler_axis": "data",
            },
        }
    )
    phase_a = textwrap.dedent(
        f"""
        import json
        import jax
        from repro.api import ExperimentSpec, build
        from repro.api.runner import _sampler_shard
        from repro.checkpoint import CheckpointManager
        from repro.fed.server import build_segment_runner
        from repro.fed.state import run_segmented

        assert len(jax.devices()) == 2
        spec = ExperimentSpec.from_json({spec_json!r})
        built = build(spec)
        assert built.sampler.shard.num_shards == 2
        seg, st = build_segment_runner(
            built.task, built.dataset, built.sampler, built.fed_config
        )
        mgr = CheckpointManager({ckpt!r}, layout=built.sampler.shard)
        st = run_segmented(st, 4, seg, ckpt_every=2, manager=mgr, max_segments=1)
        assert int(st.round) == 2
        print("PHASE_A_OK")
        """
    )
    env_a = dict(SUBPROC_ENV, REPRO_MESH_SHAPE="2,1")
    proc = subprocess.run(
        [sys.executable, "-c", phase_a],
        capture_output=True, text=True, timeout=600, env=env_a,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PHASE_A_OK" in proc.stdout

    manifest = json.loads((tmp_path / "ck" / "manifest.json").read_text())
    assert manifest["step"] == 2
    assert manifest["shard_layout"] == {"axes": [["data", 2], ["model", 1]],
                                        "axis": "data"}

    phase_b = textwrap.dedent(
        f"""
        import numpy as np
        import jax
        from repro.api import ExperimentSpec, build, run
        from repro.checkpoint import CheckpointManager

        assert len(jax.devices()) == 1
        spec = ExperimentSpec.from_json({spec_json!r})
        mgr = CheckpointManager({ckpt!r})
        hist = run(spec, ckpt_manager=mgr)
        assert len(hist.train_loss) == 4
        assert all(np.isfinite(hist.train_loss))
        # reference: the same spec, unsharded, uninterrupted, on this device
        plain = ExperimentSpec.from_dict(
            {{**spec.to_dict(),
              "execution": {{**spec.to_dict()["execution"],
                             "sampler_axis": None}}}}
        )
        ref = run(plain)
        np.testing.assert_allclose(
            hist.train_loss, ref.train_loss, rtol=1e-3, atol=1e-4
        )
        print("PHASE_B_OK")
        """
    )
    env_b = dict(SUBPROC_ENV)
    env_b.pop("XLA_FLAGS")
    proc = subprocess.run(
        [sys.executable, "-c", phase_b],
        capture_output=True, text=True, timeout=600, env=env_b,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PHASE_B_OK" in proc.stdout
