"""The compiled lax.scan server loop and the per-round Python loop must be
the SAME computation: bit-identical parameters and metrics for every sampler
procedure (ISP, RSP-with-replacement) on a tiny synthetic task.

Both paths trace the identical round body (fed/server.py:_build_round_body)
and consume the identical pre-split key stream, so this is an exact-equality
test, not an allclose one.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import make_sampler
from repro.data import synthetic_classification
from repro.fed import FedConfig, logistic_regression, run_federated
from repro.fed import server as fed_server


@pytest.fixture(scope="module")
def tiny_ds():
    return synthetic_classification(n_clients=12, total=600, seed=7)


def _run_pair(ds, name, **cfg_kw):
    cfg = FedConfig(
        rounds=5, budget=4, local_steps=2, batch_size=16, local_lr=0.05, seed=11,
        compiled=True, **cfg_kw,
    )
    sampler = make_sampler(
        name, n=ds.n_clients, budget=cfg.budget,
        **({"horizon": cfg.rounds} if name in ("kvib", "vrb") else {}),
    )
    ev = ds.batch_all_clients(jax.random.PRNGKey(99), 4)
    ev = (ev[0].reshape(-1, ev[0].shape[-1]), ev[1].reshape(-1))
    h_scan = run_federated(logistic_regression(), ds, sampler, cfg, eval_data=ev)
    h_py = run_federated(
        logistic_regression(), ds, sampler,
        dataclasses.replace(cfg, compiled=False), eval_data=ev,
    )
    return h_scan, h_py


@pytest.mark.parametrize("name", ["kvib", "uniform_isp", "vrb"])
def test_scan_matches_python_loop(tiny_ds, name):
    h_scan, h_py = _run_pair(tiny_ds, name)
    assert h_scan.train_loss == h_py.train_loss
    assert h_scan.estimator_sq_error == h_py.estimator_sq_error
    assert h_scan.cohort_size == h_py.cohort_size
    assert h_scan.test_accuracy == h_py.test_accuracy
    assert h_scan.rounds == h_py.rounds
    assert h_scan.regret.costs == h_py.regret.costs
    assert h_scan.regret.opt_costs == h_py.regret.opt_costs
    np.testing.assert_array_equal(
        np.stack(h_scan.regret.score_history), np.stack(h_py.regret.score_history)
    )


@pytest.mark.parametrize("name", ["kvib", "uniform_isp"])
def test_scan_matches_without_oracle_metrics(tiny_ds, name):
    h_scan, h_py = _run_pair(tiny_ds, name, oracle_metrics=False)
    assert h_scan.train_loss == h_py.train_loss
    assert h_scan.cohort_size == h_py.cohort_size
    assert h_scan.estimator_sq_error == [] and h_py.estimator_sq_error == []
    assert h_scan.regret.costs == [] and h_py.regret.costs == []


def test_scan_eval_schedule_matches_python(tiny_ds):
    """eval_every gating inside the scan reproduces the reference schedule:
    one accuracy entry per eval round plus the final round."""
    h_scan, h_py = _run_pair(tiny_ds, "kvib")
    # rounds=5, eval_every=5 -> evals at t=0 and t=4
    assert len(h_scan.test_accuracy) == 2
    assert h_scan.test_accuracy == h_py.test_accuracy


@pytest.mark.parametrize("name", ["kvib", "uniform_isp", "uniform_rsp"])
def test_deployable_cohort_matches_oracle_path_bitwise(tiny_ds, name):
    """With C = N the draw can never overflow (|S| <= C always), so the
    cohort-only deployable path under ``exact_oracle_equiv=True`` must
    reproduce the oracle full-mask path's draws AND parameter trajectory
    bit-for-bit: the selection keeps exactly S with unrescaled weights, and
    the scattered-zero aggregation performs the identical reduction.  (The
    default cohort-width aggregation is allclose-only — its reduction runs
    over C terms instead of N; see test_cohort_width_agg_matches_scatter.)"""
    cfg = FedConfig(rounds=5, budget=4, local_steps=2, batch_size=16, local_lr=0.05, seed=11)
    sampler = make_sampler(
        name, n=tiny_ds.n_clients, budget=cfg.budget,
        **({"horizon": cfg.rounds} if name == "kvib" else {}),
    )
    task = logistic_regression()
    h_oracle = run_federated(task, tiny_ds, sampler, cfg)
    h_dep = run_federated(
        task, tiny_ds, sampler,
        dataclasses.replace(
            cfg, oracle_metrics=False, cohort=tiny_ds.n_clients,
            exact_oracle_equiv=True,
        ),
    )
    # identical draws every round => identical sampler-state trajectory
    assert h_dep.cohort_size == h_oracle.cohort_size
    # identical parameter trajectory, observed at the endpoint
    for a, b in zip(
        jax.tree_util.tree_leaves(h_dep.final_params),
        jax.tree_util.tree_leaves(h_oracle.final_params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deployable_cohort_scan_matches_python_loop(tiny_ds):
    """The deployable cohort body is scan-safe: compiled and per-round
    dispatch agree bit-for-bit, including when overflow rescaling fires
    (C below the expected draw size)."""
    h_scan, h_py = _run_pair(tiny_ds, "kvib", oracle_metrics=False, cohort=4)
    assert h_scan.train_loss == h_py.train_loss
    assert h_scan.cohort_size == h_py.cohort_size
    assert h_scan.cohort_dropped == h_py.cohort_dropped
    # the C-slot buffer bounds the contacted cohort; drops are surfaced
    assert all(c <= 4 for c in h_scan.cohort_size)
    assert len(h_scan.cohort_dropped) == len(h_scan.cohort_size)
    for a, b in zip(
        jax.tree_util.tree_leaves(h_scan.final_params),
        jax.tree_util.tree_leaves(h_py.final_params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _width_findings(task, ds, sampler, cfg):
    """The real width-auditor pass over the built round body's jaxpr — what
    replaced this file's string-matching ``str(jax.make_jaxpr(...))`` probes
    (which passed vacuously whenever jaxpr pretty-printing changed)."""
    from repro.analysis.lint import audit_width

    body, (carry, xs) = fed_server.round_body_for_lint(task, ds, sampler, cfg, None)
    return audit_width(jax.make_jaxpr(body)(carry, xs), ds.n_clients)


def test_deployable_traces_only_cohort_local_updates(tiny_ds):
    """O(N) -> O(C): the width auditor proves the deployable round body holds
    NO client-width float intermediate — in particular not the all-clients
    (N, R, B, dim) batch buffer; it trains only the (C, R, B, dim) cohort.
    The oracle body keeps the full buffer (its diagnostics need it), which
    pins down that the auditor actually sees the buffers it polices."""
    n, r, b, dim = tiny_ds.n_clients, 2, 16, tiny_ds.features.shape[-1]
    task = logistic_regression()
    sampler = make_sampler("kvib", n=n, budget=4, horizon=5)

    base = FedConfig(rounds=5, budget=4, local_steps=r, batch_size=b)
    oracle = _width_findings(task, tiny_ds, sampler, base)
    dep = _width_findings(
        task, tiny_ds, sampler,
        dataclasses.replace(base, oracle_metrics=False, cohort=5),
    )
    assert dep == [], "\n".join(f.render() for f in dep)
    full_shape = f"float32[{n},{r},{b},{dim}]"
    assert full_shape in {f.shape for f in oracle}
    # the finding carries provenance into the batch pipeline, not just a shape
    gather = next(f for f in oracle if f.shape == full_shape)
    assert "client_batch" in gather.provenance


def test_deployable_round_has_no_client_width_delta_buffers(tiny_ds):
    """O(N*D) -> O(C*D): the width auditor proves the default deployable
    round body contains NO (N, D)-shaped delta/aggregation buffer.  The
    ``exact_oracle_equiv=True`` body keeps its per-leaf (N, 60, 10) /
    (N, 10) scatter targets (that is its contract), which pins down that the
    auditor actually sees the buffers it polices; the auditor's origin
    filtering reports each scatter target once, at the ``scatter_cohort``
    zeros allocation.  The sampler state and feedback stay (N,)-vectors —
    those are legitimate and produce no findings."""
    n, c, r, b = tiny_ds.n_clients, 5, 2, 16
    dim, n_classes = tiny_ds.features.shape[-1], 10
    task = logistic_regression(dim=dim, n_classes=n_classes)
    sampler = make_sampler("kvib", n=n, budget=4, horizon=5)

    base = FedConfig(rounds=5, budget=4, local_steps=r, batch_size=b,
                     oracle_metrics=False, cohort=c)
    cohort_width = _width_findings(task, tiny_ds, sampler, base)
    assert cohort_width == [], "(N, D) buffer leaked into the O(C*D) body:\n" + \
        "\n".join(f.render() for f in cohort_width)

    scatter = _width_findings(
        task, tiny_ds, sampler,
        dataclasses.replace(base, exact_oracle_equiv=True),
    )
    shapes = {f.shape for f in scatter}
    for shape in (f"float32[{n},{dim},{n_classes}]", f"float32[{n},{n_classes}]"):
        assert shape in shapes, f"auditor lost sight of {shape} in the scatter body"
    for f in scatter:
        if f.shape.startswith(f"float32[{n},"):
            assert "scatter_cohort" in f.provenance or "cohort.py" in f.provenance or \
                "estimator.py" in f.provenance, f.render()


@pytest.mark.parametrize("name", ["kvib", "uniform_isp", "uniform_rsp"])
def test_cohort_width_agg_matches_scatter(tiny_ds, name):
    """The cohort-width aggregation and the (N, D)-scatter aggregation are the
    same sum in a different association order: full deployable runs under both
    must agree to float tolerance for ISP and RSP samplers, including rounds
    where overflow rescaling fires (C=3 below budget=4 overflows for ISP's
    stochastic |S| and every round for RSP's fixed |S|=K)."""
    task = logistic_regression()
    cfg = FedConfig(
        rounds=6, budget=4, local_steps=2, batch_size=16, local_lr=0.05, seed=11,
        oracle_metrics=False, cohort=3,
    )
    sampler = make_sampler(
        name, n=tiny_ds.n_clients, budget=cfg.budget,
        **({"horizon": cfg.rounds} if name == "kvib" else {}),
    )
    h_cw = run_federated(task, tiny_ds, sampler, cfg)
    h_sc = run_federated(
        task, tiny_ds, sampler, dataclasses.replace(cfg, exact_oracle_equiv=True)
    )
    # identical draws/selections round for round...
    assert h_cw.cohort_size == h_sc.cohort_size
    assert h_cw.cohort_dropped == h_sc.cohort_dropped
    assert any(d > 0 for d in h_cw.cohort_dropped), "test must exercise overflow"
    np.testing.assert_allclose(h_cw.train_loss, h_sc.train_loss, rtol=1e-5, atol=1e-6)
    # ...and an allclose parameter trajectory (reduction order differs).
    for a, b in zip(
        jax.tree_util.tree_leaves(h_cw.final_params),
        jax.tree_util.tree_leaves(h_sc.final_params),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)


def test_track_scores_opt_out(tiny_ds):
    """FedConfig.track_scores=False drops the (T, N) score-history buffer from
    the oracle metrics but keeps the regret cost curves intact."""
    cfg = FedConfig(rounds=5, budget=4, local_steps=1, batch_size=16, seed=11)
    sampler = make_sampler("kvib", n=tiny_ds.n_clients, budget=cfg.budget, horizon=cfg.rounds)
    task = logistic_regression()
    h_on = run_federated(task, tiny_ds, sampler, cfg)
    h_off = run_federated(
        task, tiny_ds, sampler, dataclasses.replace(cfg, track_scores=False)
    )
    assert h_off.regret.score_history == []
    assert len(h_on.regret.score_history) == cfg.rounds
    # scores are diagnostic-only: the run itself is unchanged
    assert h_off.train_loss == h_on.train_loss
    assert h_off.regret.costs == h_on.regret.costs
    assert h_off.regret.opt_costs == h_on.regret.opt_costs
    assert float(h_off.regret.dynamic_regret()[-1]) == float(h_on.regret.dynamic_regret()[-1])
    # the score-replay diagnostic reports its unavailability, not an np.stack crash
    with pytest.raises(ValueError, match="track_scores"):
        h_off.regret.static_regret()


def test_rsp_regret_marginals_are_valid(tiny_ds):
    """Satellite bugfix: RSP p_eff = K * q clipped into (0, 1] — the regret
    diagnostic must never see a 'marginal' above 1 even when one client
    dominates the draw distribution."""
    ds = synthetic_classification(n_clients=6, total=300, power=3.5, seed=0)
    cfg = FedConfig(rounds=8, budget=5, local_steps=1, batch_size=8, local_lr=0.05)
    sampler = make_sampler("vrb", n=ds.n_clients, budget=cfg.budget, horizon=cfg.rounds)
    h = run_federated(logistic_regression(), ds, sampler, cfg)
    # cost = sum_i a_i^2 / p_i with p in (0,1] is >= sum_i a_i^2; a p>1 leak
    # would push costs BELOW that floor.
    for cost, scores in zip(h.regret.costs, h.regret.score_history):
        assert cost >= float(np.sum(np.square(scores))) - 1e-6
