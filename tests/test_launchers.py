"""End-to-end launcher CLIs (train/serve) and the sliding-window variant."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}


def test_sw_variant_decode_consistency():
    """The beyond-paper sliding-window llama variant: prefill+decode match
    the full forward (window masking identical across paths)."""
    from repro.configs.llama3_2_1b import SW_CONFIG

    cfg = SW_CONFIG.reduced(sliding_window=8)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0, cfg.vocab)
    logits_full, _ = transformer.forward(params, cfg, tokens)
    logits_pre, caches = transformer.prefill(params, cfg, tokens[:, :s], max_seq=s + 4)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(logits_full[:, s - 1]),
        rtol=2e-2, atol=2e-2,
    )
    logits_dec, _ = transformer.decode_step(
        params, cfg, tokens[:, s : s + 1], caches, jnp.asarray(s, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, s]),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.slow  # fresh-interpreter CLI: jax import + model compile per run
def test_train_cli_end_to_end(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "smollm-360m", "--reduced", "--rounds", "3",
         "--clients", "8", "--budget", "3", "--cohort", "4",
         "--seq", "32", "--local-batch", "2",
         "--ckpt", str(tmp_path / "fl")],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "round   2" in proc.stdout
    assert "final checkpoint" in proc.stdout
    assert (tmp_path / "fl.npz").exists()
    # losses finite
    losses = [float(l.split("loss=")[1].split()[0]) for l in proc.stdout.splitlines() if "loss=" in l]
    assert all(np.isfinite(losses)) and len(losses) == 3


@pytest.mark.slow  # three fresh-interpreter CLI runs with model compiles
def test_compiled_train_survives_sigkill_and_resumes(tmp_path):
    """Acceptance: a SIGKILL'd ``--compiled`` run resumes from the
    CheckpointManager manifest and converges to the SAME final params as an
    uninterrupted run.  REPRO_KILL_AFTER_SEGMENTS makes the launcher SIGKILL
    itself right after publishing segment 1 of 2 — a real process death, not
    a cooperative exit — then ``--resume`` finishes the horizon."""
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-360m", "--reduced", "--compiled",
        "--rounds", "4", "--clients", "8", "--budget", "3", "--cohort", "4",
        "--seq", "32", "--local-batch", "2", "--ckpt-every", "2",
    ]
    base = subprocess.run(
        args + ["--ckpt", str(tmp_path / "base")],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert base.returncode == 0, base.stderr[-2000:]

    killed = subprocess.run(
        args + ["--ckpt", str(tmp_path / "kill")],
        capture_output=True, text=True, timeout=600,
        env={**_ENV, "REPRO_KILL_AFTER_SEGMENTS": "1"},
    )
    assert killed.returncode == -9, (killed.returncode, killed.stderr[-2000:])
    assert "final checkpoint" not in killed.stdout  # it really died mid-run
    ckpt_dir = tmp_path / "kill_ckpts"
    assert (ckpt_dir / "manifest.json").exists()
    import json
    assert json.loads((ckpt_dir / "manifest.json").read_text())["step"] == 2

    resumed = subprocess.run(
        args + ["--ckpt", str(tmp_path / "kill"), "--resume"],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    assert "resumed from checkpoint step 2" in resumed.stdout
    # the resumed History covers the whole horizon, pre-kill rounds included
    assert "round   0" in resumed.stdout and "round   3" in resumed.stdout

    a = np.load(tmp_path / "base.npz")
    b = np.load(tmp_path / "kill.npz")
    assert a.files == b.files and len(a.files) > 0
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@pytest.mark.slow  # four fresh-interpreter CLI runs with model compiles
def test_spec_cli_reproduces_flag_run_through_kill_resume(tmp_path):
    """Acceptance for the spec front door: ``--spec`` consuming a
    ``--dump-spec``-emitted file reproduces the flag-driven run's final
    params exactly — including through a SIGKILL + ``--resume`` cycle whose
    manifest fingerprint derives from ``config_fingerprint(spec.to_dict())``."""
    flags = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-360m", "--reduced", "--compiled",
        "--rounds", "4", "--clients", "8", "--budget", "3", "--cohort", "4",
        "--seq", "32", "--local-batch", "2", "--ckpt-every", "2",
    ]
    base = subprocess.run(
        flags + ["--ckpt", str(tmp_path / "flags")],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert base.returncode == 0, base.stderr[-2000:]

    dumped = subprocess.run(
        flags + ["--dump-spec"],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert dumped.returncode == 0, dumped.stderr[-2000:]
    spec_path = tmp_path / "exp.json"
    spec_path.write_text(dumped.stdout)

    spec_args = [
        sys.executable, "-m", "repro.launch.train",
        "--spec", str(spec_path), "--ckpt", str(tmp_path / "spec"),
    ]
    killed = subprocess.run(
        spec_args, capture_output=True, text=True, timeout=600,
        env={**_ENV, "REPRO_KILL_AFTER_SEGMENTS": "1"},
    )
    assert killed.returncode == -9, (killed.returncode, killed.stderr[-2000:])
    import json
    manifest = json.loads((tmp_path / "spec_ckpts" / "manifest.json").read_text())
    assert manifest["step"] == 2
    # the manifest fingerprint IS the spec fingerprint
    from repro.api import ExperimentSpec
    from repro.checkpoint import config_fingerprint

    spec = ExperimentSpec.load(str(spec_path))
    assert manifest["config_fingerprint"] == config_fingerprint(spec.to_dict())

    resumed = subprocess.run(
        spec_args + ["--resume"],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert resumed.returncode == 0, resumed.stderr[-2000:]
    assert "resumed from checkpoint step 2" in resumed.stdout

    a = np.load(tmp_path / "flags.npz")
    b = np.load(tmp_path / "spec.npz")
    assert a.files == b.files and len(a.files) > 0
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@pytest.mark.slow  # fresh-interpreter CLI: jax import + model compile per run
def test_serve_cli_end_to_end():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "xlstm-125m", "--reduced", "--batch", "2",
         "--prompt-len", "8", "--new-tokens", "4"],
        capture_output=True, text=True, timeout=600, env=_ENV,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "decoded 3 steps" in proc.stdout
    assert "generated ids" in proc.stdout
