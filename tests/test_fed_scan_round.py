"""The compiled mesh-parallel federated scan (fed.round.build_fed_scan).

The scan's per-round body must be the SAME computation as the launcher's host
loop: identical key stream, identical draws/cohorts, identical batches (the
device-side gather reproduces ``host_gather_cohort_batches``'s
fold_in(k_data, client_id) stream), and the same ``build_round_step`` round
math — so the two substrates may differ only by float reassociation.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import estimator, make_sampler
from repro.data import synthetic_tokens
from repro.fed import cohort as fed_cohort
from repro.fed.round import RoundSpec, build_fed_scan, build_round_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=64, d_ff=128, vocab=128)
    ds = synthetic_tokens(n_clients=8, seq_len=16, vocab=cfg.vocab, total_seqs=256, seed=3)
    spec = RoundSpec(cohort=3, local_steps=2, local_lr=0.05, local_batch=2)
    sampler = make_sampler("kvib", n=ds.n_clients, budget=2, horizon=4)
    return cfg, ds, spec, sampler


def _host_loop_reference(cfg, ds, spec, sampler, key, rounds):
    """The repro.launch.train host loop, key-for-key."""
    from repro.models import transformer

    params = transformer.init_params(cfg, key)
    lam = np.asarray(ds.lam)
    s_state = sampler.init()
    round_step = jax.jit(build_round_step(cfg, spec))
    losses, cohorts = [], []
    for _ in range(rounds):
        key, k_draw, k_data = jax.random.split(key, 3)
        p = sampler.probabilities(s_state)
        draw = sampler.sample_from(p, k_draw)
        w_full = estimator.client_weights(
            draw, jnp.asarray(lam), sampler.procedure, sampler.budget
        )
        sel = fed_cohort.select_cohort(
            draw.mask, w_full, spec.cohort, jax.random.fold_in(k_draw, 1)
        )
        tokens, targets = fed_cohort.host_gather_cohort_batches(
            ds, sel, k_data, spec.local_steps, spec.local_batch
        )
        params, norms, loss = round_step(params, tokens, targets, sel.weights)
        ids, valid = np.asarray(sel.ids), np.asarray(sel.valid)
        fb = np.zeros(ds.n_clients, np.float32)
        fb[ids[valid]] = lam[ids[valid]] * np.asarray(norms)[valid]
        s_state = sampler.update(s_state, draw, jnp.asarray(fb))
        losses.append(float(loss))
        cohorts.append(int(valid.sum()))
    return params, losses, cohorts


def test_fed_scan_matches_host_loop(tiny_setup):
    """One jitted scan over rounds == the per-round host loop: same draws,
    same batches, allclose parameters and losses."""
    from repro.models import transformer

    cfg, ds, spec, sampler = tiny_setup
    rounds = 3
    key = jax.random.PRNGKey(5)
    params0 = transformer.init_params(cfg, key)

    k = key
    pairs = []
    for _ in range(rounds):
        k, k_draw, k_data = jax.random.split(k, 3)
        pairs.append(jnp.stack([k_draw, k_data]))
    run = build_fed_scan(cfg, spec, sampler, ds)
    params, s_state, metrics = run(params0, sampler.init(), jnp.stack(pairs))

    params_ref, losses_ref, cohorts_ref = _host_loop_reference(
        cfg, ds, spec, sampler, jax.random.PRNGKey(5), rounds
    )
    assert [int(c) for c in np.asarray(metrics["cohort_size"])] == cohorts_ref
    np.testing.assert_allclose(
        np.asarray(metrics["loss"]), np.asarray(losses_ref), rtol=1e-4, atol=1e-5
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params_ref)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-4, rtol=2e-3
        )


def test_fed_scan_runs_cohort_sequential(tiny_setup):
    """The scan body also drives the FSDP-oriented cohort_sequential schedule
    (the same math as client_parallel — see test_round.py — so losses and
    params must agree across schedules inside the scan too)."""
    import dataclasses

    cfg, ds, spec, sampler = tiny_setup
    from repro.models import transformer

    key = jax.random.PRNGKey(5)
    params0 = transformer.init_params(cfg, key)
    pairs = jnp.stack([
        jnp.stack(list(jax.random.split(jax.random.PRNGKey(9 + t), 2))) for t in range(2)
    ])
    outs = {}
    for mode in ("client_parallel", "cohort_sequential"):
        run = build_fed_scan(
            dataclasses.replace(cfg, round_mode=mode), spec, sampler, ds
        )
        # run() donates its params arg on non-CPU backends; hand each mode its
        # own copy so the second iteration doesn't see deleted buffers.
        params_in = jax.tree_util.tree_map(jnp.copy, params0)
        outs[mode] = run(params_in, sampler.init(), pairs)
    p_cp, _, m_cp = outs["client_parallel"]
    p_cs, _, m_cs = outs["cohort_sequential"]
    np.testing.assert_allclose(
        np.asarray(m_cp["loss"]), np.asarray(m_cs["loss"]), rtol=1e-4, atol=1e-5
    )
    for a, b in zip(jax.tree_util.tree_leaves(p_cp), jax.tree_util.tree_leaves(p_cs)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-4, rtol=2e-3
        )


def test_scan_body_is_cohort_width_and_f32_by_audit():
    """The pod-scale scan body honors the cohort-width and dtype contracts,
    proven by the jaxpr auditors (repro.analysis.lint) on the abstractly
    traced body — the structural claim in build_fed_scan's docstring ('every
    buffer with a parameter axis is C-wide'), machine-checked instead of
    string-matched.  The client count is 13 (prime, distinct from every
    model/batch dimension) so the auditor's client-axis detection cannot
    collide with d_model/d_head/seq/vocab axes."""
    from repro.analysis.lint import audit_dtypes, audit_width
    from repro.fed.round import scan_body_for_lint

    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=64, d_ff=128, vocab=128)
    ds = synthetic_tokens(n_clients=13, seq_len=16, vocab=cfg.vocab, total_seqs=256, seed=3)
    spec = RoundSpec(cohort=3, local_steps=2, local_lr=0.05, local_batch=2)
    sampler = make_sampler("kvib", n=ds.n_clients, budget=2, horizon=4)

    body, (carry, xs) = scan_body_for_lint(cfg, spec, sampler, ds)
    closed = jax.make_jaxpr(body)(carry, xs)
    width = audit_width(closed, ds.n_clients)
    assert width == [], "\n".join(f.render() for f in width)
    dtypes = audit_dtypes(closed, target="scan_body")
    assert dtypes == [], "\n".join(f.render() for f in dtypes)


@pytest.mark.slow  # fresh interpreter: forced 2-device CPU mesh + model compile
def test_compiled_scan_on_two_device_mesh_subprocess():
    """Acceptance: the compiled scan drives a fed/round.py round body on a
    >=2-device mesh end-to-end (2 forced CPU host devices, data axis = 2)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "smollm-360m", "--reduced", "--compiled",
         "--rounds", "3", "--clients", "8", "--budget", "3", "--cohort", "4",
         "--seq", "32", "--local-batch", "2"],
        capture_output=True, text=True, timeout=600,
        env={
            "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
            "REPRO_MESH_SHAPE": "2,1",
        },
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "compiled scan on mesh" in proc.stdout
    assert "'data': 2" in proc.stdout
    assert "round   2" in proc.stdout
    losses = [
        float(l.split("loss=")[1].split()[0])
        for l in proc.stdout.splitlines() if "loss=" in l
    ]
    assert len(losses) == 3 and all(np.isfinite(losses))
    assert "rounds in one dispatch" in proc.stdout
