"""Appendix E.1: availability-corrected estimation stays unbiased."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimator, samplers
from repro.core.stragglers import (
    ZeroAvailabilityError,
    availability_weights,
    available_draw,
)


def test_unbiased_under_stragglers():
    n, k, d = 24, 8, 12
    g = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    lam = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(n))
    q = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.4, maxval=1.0)
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))

    s = samplers.make_sampler("kvib", n=n, budget=k, gamma=0.05)
    st = s.init()
    # burn-in
    fb = lam * jnp.linalg.norm(g, axis=1)
    for t in range(3):
        dr = s.sample(st, jax.random.PRNGKey(10 + t))
        st = s.update(st, dr, fb * dr.mask)

    trials = 6000
    keys = jax.random.split(jax.random.PRNGKey(5), trials)

    def one(key):
        k1, k2 = jax.random.split(key)
        dr = s.sample(st, k1)
        avail = jax.random.uniform(k2, (n,)) < q
        dr = available_draw(dr, avail)
        w = availability_weights(dr, lam, q, s.procedure, s.budget)
        return estimator.aggregate_stacked(g, w)

    ests = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, axis=0))
    se = np.asarray(jnp.std(ests, axis=0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - target) < 5.0 * se + 1e-4)


def test_unavailable_clients_never_included():
    n, k = 16, 6
    s = samplers.make_sampler("uniform_isp", n=n, budget=k)
    st = s.init()
    avail = jnp.arange(n) % 2 == 0  # odd clients offline
    for t in range(30):
        dr = available_draw(s.sample(st, jax.random.PRNGKey(t)), avail)
        assert not bool(jnp.any(jnp.logical_and(dr.mask, ~avail)))


def test_composed_draw_contract():
    # available_draw(dr, avail, q) composes q into the draw probabilities, so
    # the plain estimator on the composed draw IS the availability-corrected
    # estimator on the masked draw.
    n, k = 20, 7
    lam = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(n))
    q = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.3, maxval=1.0)
    s = samplers.make_sampler("kvib", n=n, budget=k, gamma=0.05)
    st = s.init()
    dr = s.sample(st, jax.random.PRNGKey(3))
    avail = jax.random.uniform(jax.random.PRNGKey(4), (n,)) < q

    composed = available_draw(dr, avail, q)
    np.testing.assert_allclose(
        np.asarray(composed.marginals), np.asarray(q * dr.marginals), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(composed.draw_probs), np.asarray(q * dr.draw_probs), rtol=1e-6
    )
    assert not bool(jnp.any(jnp.logical_and(composed.mask, ~avail)))

    masked = available_draw(dr, avail)
    w_legacy = availability_weights(masked, lam, q, s.procedure, s.budget)
    w_composed = estimator.client_weights(composed, lam, s.procedure, s.budget)
    np.testing.assert_allclose(
        np.asarray(w_composed), np.asarray(w_legacy), rtol=1e-5, atol=1e-7
    )


def test_composed_draw_zero_q_excluded():
    # q == 0 clients are excluded from the mask even if the raw availability
    # bit is (incorrectly) on for them.
    n, k = 12, 5
    s = samplers.make_sampler("uniform_isp", n=n, budget=k)
    q = jnp.where(jnp.arange(n) < 4, 0.0, 1.0)
    avail = jnp.ones((n,), dtype=bool)  # claims everyone is up
    for t in range(20):
        dr = available_draw(s.sample(s.init(), jax.random.PRNGKey(t)), avail, q)
        assert not bool(jnp.any(jnp.logical_and(dr.mask, q == 0.0)))


def test_zero_availability_raises_on_host():
    # Host path: a drawn client with q == 0 is a configuration error and must
    # raise a named exception instead of silently clamping to 1e-30.
    n, k = 10, 4
    lam = jnp.ones(n) / n
    s = samplers.make_sampler("uniform_isp", n=n, budget=k)
    dr = s.sample(s.init(), jax.random.PRNGKey(0))
    q = jnp.zeros(n)  # every client has zero availability
    with pytest.raises(ZeroAvailabilityError):
        availability_weights(dr, lam, q, s.procedure, s.budget)


def test_zero_availability_masks_to_zero_in_trace():
    # In-trace the same condition cannot raise; the weight must be exactly
    # 0.0 (masked out), never a huge 1/1e-30 blow-up.
    n, k = 10, 4
    lam = jnp.ones(n) / n
    s = samplers.make_sampler("uniform_isp", n=n, budget=k)
    dr = s.sample(s.init(), jax.random.PRNGKey(0))
    q = jnp.where(jnp.arange(n) < n // 2, 0.0, 1.0)

    @jax.jit
    def weights(q_):
        return availability_weights(dr, lam, q_, s.procedure, s.budget)

    w = np.asarray(weights(q))
    assert np.all(w[: n // 2] == 0.0)
    assert np.all(np.isfinite(w))
