"""Appendix E.1: availability-corrected estimation stays unbiased."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, samplers
from repro.core.stragglers import availability_weights, available_draw


def test_unbiased_under_stragglers():
    n, k, d = 24, 8, 12
    g = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    lam = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(n))
    q = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.4, maxval=1.0)
    target = np.asarray(estimator.full_aggregate_stacked(g, lam))

    s = samplers.make_sampler("kvib", n=n, budget=k, gamma=0.05)
    st = s.init()
    # burn-in
    fb = lam * jnp.linalg.norm(g, axis=1)
    for t in range(3):
        dr = s.sample(st, jax.random.PRNGKey(10 + t))
        st = s.update(st, dr, fb * dr.mask)

    trials = 6000
    keys = jax.random.split(jax.random.PRNGKey(5), trials)

    def one(key):
        k1, k2 = jax.random.split(key)
        dr = s.sample(st, k1)
        avail = jax.random.uniform(k2, (n,)) < q
        dr = available_draw(dr, avail)
        w = availability_weights(dr, lam, q, s.procedure, s.budget)
        return estimator.aggregate_stacked(g, w)

    ests = jax.vmap(one)(keys)
    mean = np.asarray(jnp.mean(ests, axis=0))
    se = np.asarray(jnp.std(ests, axis=0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - target) < 5.0 * se + 1e-4)


def test_unavailable_clients_never_included():
    n, k = 16, 6
    s = samplers.make_sampler("uniform_isp", n=n, budget=k)
    st = s.init()
    avail = jnp.arange(n) % 2 == 0  # odd clients offline
    for t in range(30):
        dr = available_draw(s.sample(st, jax.random.PRNGKey(t)), avail)
        assert not bool(jnp.any(jnp.logical_and(dr.mask, ~avail)))
