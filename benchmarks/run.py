"""Benchmark harness — one entry per paper table/figure + framework benches.

Emits ``name,us_per_call,derived`` CSV rows.  Experiment-derived rows read
the JSON artifacts produced by the example drivers (results/*.json); compute
benches time the hot paths on this host.  The federated benches construct
their experiment pieces through ``repro.api`` specs (``api.build``), so the
benchmarked configuration is the same serializable description every other
front door consumes.

  PYTHONPATH=src python -m benchmarks.run [--filter substr]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = os.environ.get("REPRO_RESULTS", "results")
ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _timeit(fn, *args, reps=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
# Table: sampler solver scaling (paper Appendix G — O(N log N) claim)
# ---------------------------------------------------------------------------


def bench_solver_scaling() -> None:
    from repro.core import solver

    for n in (1_000, 10_000, 100_000, 1_000_000):
        a = jax.random.uniform(jax.random.PRNGKey(0), (n,)) + 1e-3
        f = jax.jit(lambda a, n=n: solver.isp_probabilities(a, n // 10))
        us = _timeit(f, a)
        row(f"kvib_solver_n{n}", us, f"probabilities for N={n} clients")


# ---------------------------------------------------------------------------
# Table: server aggregation (fused kernel vs two-pass reference)
# ---------------------------------------------------------------------------


def bench_fused_aggregation() -> None:
    from repro.kernels import ref
    from repro.kernels.fused_weighted_agg import fused_weighted_agg

    c, d = 16, 1 << 20
    g = jax.random.normal(jax.random.PRNGKey(0), (c, d), jnp.float32)
    w = jax.random.uniform(jax.random.PRNGKey(1), (c,))

    us_ref = _timeit(jax.jit(ref.weighted_agg_reference), g, w, reps=5)
    row("weighted_agg_reference", us_ref, f"two-output jnp path C={c} D={d}")
    us_k = _timeit(
        lambda g, w: fused_weighted_agg(g, w, block_d=4096, interpret=True), g, w,
        reps=1, warmup=1,
    )
    row("fused_weighted_agg_interp", us_k, "Pallas kernel (interpret mode; TPU target)")


# ---------------------------------------------------------------------------
# Table: federated round step (paper's Algorithm 1 at simulation scale)
# ---------------------------------------------------------------------------


def bench_round_step() -> None:
    from repro.configs import get_config
    from repro.fed.round import RoundSpec, build_round_step
    from repro.models import transformer

    cfg = get_config("smollm-360m").reduced(n_layers=2, d_model=128, d_ff=256, vocab=256)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    c, r, b, s = 4, 2, 2, 64
    tok = jax.random.randint(jax.random.PRNGKey(1), (c, r, b, s), 0, cfg.vocab)
    w = jnp.full((c,), 0.25)
    step = jax.jit(build_round_step(cfg, RoundSpec(cohort=c, local_steps=r, local_lr=0.05)))
    us = _timeit(step, params, tok, tok, w, reps=3)
    tokens = c * r * b * s
    row("fl_round_step_reduced", us, f"{tokens} tokens/round client_parallel")


# ---------------------------------------------------------------------------
# Table: compiled scan loop vs per-round Python dispatch (fed/server.py)
# ---------------------------------------------------------------------------


def bench_fed_round_scan() -> None:
    """Whole-run lax.scan vs the per-round reference loop at N=100, T=50.

    The Python path pays 1 jit dispatch + 5 host transfers per round (loss,
    cohort, sq-error, cost, opt-cost); the scan path pays 1 dispatch + 1
    transfer for the ENTIRE run — 6T vs 2 host round-trips (150x fewer at
    T=50).  Both execute the identical round body."""
    import jax.numpy as jnp

    from repro import api
    from repro.fed import server as fed_server

    n, t_rounds = 100, 50
    spec = api.ExperimentSpec(
        task=api.TaskSpec(
            name="logreg", dataset="synthetic_classification",
            dataset_kwargs=dict(n_clients=n, total=200 * n, seed=0),
        ),
        sampler=api.SamplerSpec(name="kvib", kwargs=dict(horizon=t_rounds)),
        federation=api.FederationSpec(
            rounds=t_rounds, budget=10, local_steps=1, batch_size=8,
        ),
    )
    built = api.build(spec)
    task, ds, sampler, cfg = built.task, built.dataset, built.sampler, built.fed_config
    body = fed_server._build_round_body(task, ds, sampler, cfg, None)

    key = jax.random.PRNGKey(0)
    params = task.init(key)
    opt = cfg.server_opt.init(params)
    ss = sampler.init()
    keys = jax.random.split(key, t_rounds * 2).reshape(t_rounds, 2, 2)
    ts = jnp.arange(t_rounds, dtype=jnp.int32)

    @jax.jit
    def scan_all(params, opt, ss, keys):
        return jax.lax.scan(body, (params, opt, ss), (ts, keys[:, 0], keys[:, 1]))

    step = jax.jit(body)

    us_scan = _timeit(scan_all, params, opt, ss, keys, reps=5, warmup=2) / t_rounds

    def python_loop(params, opt, ss, keys):
        carry = (params, opt, ss)
        for t in range(t_rounds):
            carry, m = step(carry, (ts[t], keys[t, 0], keys[t, 1]))
            # The reference loop's per-round host syncs.
            for v in m.values():
                float(jnp.sum(v))
        return carry

    us_py = _timeit(python_loop, params, opt, ss, keys, reps=5, warmup=2) / t_rounds

    row("fed_round_scan", us_scan, f"compiled lax.scan N={n} T={t_rounds}; 2 host round-trips/run")
    row(
        "fed_round_python",
        us_py,
        f"per-round dispatch; {6 * t_rounds} host round-trips/run ({us_py / us_scan:.2f}x slower/round)",
    )


# ---------------------------------------------------------------------------
# Table: segmented compiled horizon vs monolithic scan (preemption-safety tax)
# ---------------------------------------------------------------------------


def bench_fed_scan_segmented() -> None:
    """What does cutting the compiled horizon into checkpointable segments
    cost?  Runs the same T-round horizon (fed/server.py segment runner,
    identical results by construction) as ONE segment vs segments of
    ``ckpt_every=50`` rounds — the overhead is purely the extra host
    dispatches and the metric-buffer stitching, NOT checkpoint I/O (no
    manager attached), which is the steady-state tax a preemption-safe run
    pays every round.  Target: <10% us/round at ckpt_every=50.  Emits
    ``RESULTS/BENCH_fed_scan_segmented.json`` with the lower-is-better
    segmented/monolithic ratio for the regression gate."""
    from repro import api
    from repro.fed import server as fed_server
    from repro.fed.state import run_segmented

    n, t_rounds, every = 100, 100, 50
    spec = api.ExperimentSpec(
        task=api.TaskSpec(
            name="logreg", dataset="synthetic_classification",
            dataset_kwargs=dict(n_clients=n, total=40 * n, seed=0),
        ),
        sampler=api.SamplerSpec(name="kvib", kwargs=dict(horizon=t_rounds)),
        federation=api.FederationSpec(
            rounds=t_rounds, budget=10, local_steps=1, batch_size=8,
        ),
    )
    built = api.build(spec)
    # donate=False: _timeit re-runs from the same initial state, which
    # donation would invalidate on accelerator backends.
    segment, state0 = fed_server.build_segment_runner(
        built.task, built.dataset, built.sampler, built.fed_config, None,
        donate=False,
    )

    def run_with(ckpt_every):
        def go():
            out = run_segmented(state0, t_rounds, segment, ckpt_every=ckpt_every)
            jax.block_until_ready(out.metrics)
        return go

    modes = (("monolithic", 0), (f"ckpt{every}", every))
    goes = {mode: run_with(ckpt_every) for mode, ckpt_every in modes}
    for go in goes.values():  # compile both segment lengths up front
        go()
    # Interleaved best-of-k: the ratio is the payload, and a mean would let a
    # load spike during one mode's window masquerade as segmentation cost.
    best = {mode: float("inf") for mode in goes}
    for _ in range(8):
        for mode, go in goes.items():
            t0 = time.perf_counter()
            go()
            best[mode] = min(best[mode], time.perf_counter() - t0)
    us = {mode: b / t_rounds * 1e6 for mode, b in best.items()}
    for mode, ckpt_every in modes:
        row(
            f"fed_scan_segmented_{mode}", us[mode],
            f"us/round, N={n} T={t_rounds} "
            + ("one segment" if ckpt_every == 0 else f"{t_rounds // ckpt_every} segments"),
        )
    ratio = us[f"ckpt{every}"] / us["monolithic"]
    row("fed_scan_segmented_overhead", 0,
        f"segmented/monolithic us-per-round ratio: {ratio:.3f}x (target < 1.10)")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_scan_segmented.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_scan_segmented",
                "entries": [{
                    "n": n, "rounds": t_rounds, "ckpt_every": every,
                    "monolithic_us_per_round": us["monolithic"],
                    "segmented_us_per_round": us[f"ckpt{every}"],
                }],
                # regression-gate ratios: LOWER is better
                "ratios": {f"segmented_ckpt{every}_over_monolithic": ratio},
            },
            f, indent=2,
        )


# ---------------------------------------------------------------------------
# Table: deployable cohort-only round vs oracle all-clients round (O(C) vs O(N))
# ---------------------------------------------------------------------------


def bench_fed_round_cohort() -> None:
    """us/round vs N at fixed K for the two metric fidelities of fed/server.py:
    oracle (trains all N clients, O(N) local-update compute) vs deployable
    (trains only the static C-slot cohort, O(C) local-update compute plus
    O(N) sampler/scatter bookkeeping).  Oracle grows linearly in N; the
    deployable curve should stay roughly flat.  Emits the per-N pairs to
    ``RESULTS/BENCH_fed_round_cohort.json`` so the perf trajectory records
    deployable-mode us/round across PRs."""
    from repro import api
    from repro.fed import server as fed_server

    k, c = 10, 20

    def spec_for(n, oracle):
        return api.ExperimentSpec(
            task=api.TaskSpec(
                name="logreg", dataset="synthetic_classification",
                dataset_kwargs=dict(n_clients=n, total=40 * n, seed=0),
            ),
            sampler=api.SamplerSpec(name="kvib", kwargs=dict(horizon=100)),
            federation=api.FederationSpec(
                budget=k, local_steps=1, batch_size=16,
                cohort=None if oracle else c,
            ),
            execution=api.ExecutionSpec(oracle_metrics=oracle),
        )

    entries = []
    for n in (64, 256, 1024):
        us = {}
        params = None
        for mode, oracle in (("oracle", True), ("deployable", False)):
            built = api.build(spec_for(n, oracle))
            task, ds, sampler, cfg = (
                built.task, built.dataset, built.sampler, built.fed_config,
            )
            if params is None:
                params = task.init(jax.random.PRNGKey(0))
            xs = (jnp.zeros((), jnp.int32), jax.random.PRNGKey(1), jax.random.PRNGKey(2))
            body = fed_server._build_round_body(task, ds, sampler, cfg, None)
            carry = (params, cfg.server_opt.init(params), sampler.init())
            us[mode] = _timeit(jax.jit(body), carry, xs, reps=10, warmup=2)
            row(f"fed_round_cohort_n{n}_{mode}", us[mode], f"K={k} C={c} one round body")
        entries.append(
            {"n": n, "budget": k, "cohort": c,
             "oracle_us": us["oracle"], "deployable_us": us["deployable"],
             "oracle_over_deployable": us["oracle"] / us["deployable"]}
        )
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_round_cohort.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_round_cohort",
                "entries": entries,
                # regression-gate ratios: LOWER is better (benchmarks/check_regression.py)
                "ratios": {
                    "deployable_over_oracle_n1024":
                        entries[-1]["deployable_us"] / entries[-1]["oracle_us"],
                },
            },
            f, indent=2,
        )


# ---------------------------------------------------------------------------
# Table: cohort-width deployable round — us/round and live bytes flat in N
# ---------------------------------------------------------------------------


def bench_fed_cohort_width() -> None:
    """The tentpole claim of the cohort-width fast path: at fixed K/C the
    deployable round's cost must NOT grow with the client population N.

    Times the deployable round body in both aggregation widths — the default
    O(C*D) cohort-width path and the legacy O(N*D) scatter path
    (``exact_oracle_equiv=True``) — across N, and records the compiled
    round's peak live bytes.  Emits ``RESULTS/BENCH_fed_cohort_width.json``
    with lower-is-better flatness ratios for the regression gate.

    Design notes: the task is the MLP (D ~ 26k params) so the O(*D) costs
    dominate the O(N) sampler-vector ops, as they do at real scale; client
    sizes are uniform (``power=0.0``) so the padded dataset's max-client size
    stays constant in N — under the default power law s_max grows with N and
    the batch *gather* walks a multi-GB array, a simulation-harness artifact
    that would otherwise be billed to the round."""
    from repro import api
    from repro.fed import server as fed_server

    k, c = 10, 20
    entries = []
    for n in (64, 256, 1024):
        spec = api.ExperimentSpec(
            task=api.TaskSpec(
                name="mlp",
                kwargs=dict(dim=60, n_classes=10, hidden=128, depth=2),
                dataset="synthetic_classification",
                dataset_kwargs=dict(n_clients=n, total=40 * n, power=0.0, seed=0),
            ),
            sampler=api.SamplerSpec(name="kvib", kwargs=dict(horizon=100)),
            federation=api.FederationSpec(
                budget=k, local_steps=1, batch_size=16, cohort=c,
            ),
            execution=api.ExecutionSpec(oracle_metrics=False),
        )
        built = api.build(spec)
        task, ds, sampler = built.task, built.dataset, built.sampler
        base = built.fed_config
        params = task.init(jax.random.PRNGKey(0))
        xs = (jnp.zeros((), jnp.int32), jax.random.PRNGKey(1), jax.random.PRNGKey(2))
        entry = {"n": n, "budget": k, "cohort": c}
        for mode, cfg in (
            ("cohort_width", base),
            ("scatter", dataclasses.replace(base, exact_oracle_equiv=True)),
        ):
            body = fed_server._build_round_body(task, ds, sampler, cfg, None)
            carry = (params, cfg.server_opt.init(params), sampler.init())
            jitted = jax.jit(body)  # one wrapper: _timeit and memory_analysis share the compile
            entry[f"{mode}_us"] = _timeit(jitted, carry, xs, reps=20, warmup=3)
            row(f"fed_cohort_width_n{n}_{mode}", entry[f"{mode}_us"],
                f"K={k} C={c} deployable round body")
            try:
                ma = jitted.lower(carry, xs).compile().memory_analysis()
                entry[f"{mode}_peak_bytes"] = int(
                    ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes
                )
            except Exception:
                entry[f"{mode}_peak_bytes"] = None
        entries.append(entry)
    flat = entries[-1]["cohort_width_us"] / entries[0]["cohort_width_us"]
    slope = entries[-1]["scatter_us"] / entries[0]["scatter_us"]
    row("fed_cohort_width_flatness", 0,
        f"cohort-width N=64->1024: {flat:.2f}x (scatter path: {slope:.2f}x)")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_cohort_width.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_cohort_width",
                "entries": entries,
                # regression-gate ratios: LOWER is better
                "ratios": {"cohort_width_n1024_over_n64": flat},
            },
            f, indent=2,
        )


# ---------------------------------------------------------------------------
# Table: million-client sampler round — per-client cost flat in N
# ---------------------------------------------------------------------------


def bench_fed_sampler_scale() -> None:
    """The tentpole claim of the sharded sampler stack: at fixed budget K the
    full sampler round — sharded water-filling solve, Poisson draw, feedback
    update — costs O(N/S) per device with a CONSTANT per-client price.

    Times the jitted sampler round at N = 10^4..10^6 (no model — the sampler
    is the only N-sized object, which is exactly the point) and records the
    compiled round's live bytes.  The gate ratios normalize per client:
    us/client and bytes/client from N=10^4 to N=10^6 must stay <= 1.5x
    (lower-is-better flatness, ``benchmarks/check_regression.py``).  CPU CI
    runs the degenerate S=1 mesh; per-client normalization makes the gate
    mesh-size independent — on an S-shard mesh every shard holds N/S clients
    at the same per-client price."""
    from repro.core import make_sampler
    from repro.launch.mesh import ShardSpec

    k = 64
    entries = []
    for n in (10_000, 100_000, 1_000_000):
        sampler = dataclasses.replace(
            make_sampler("kvib", n=n, budget=k, horizon=100),
            shard=ShardSpec(),
        )

        @jax.jit
        def sampler_round(state, key, sampler=sampler):
            p = sampler.probabilities(state)
            draw = sampler.sample_from(p, key)
            return sampler.update(state, draw, draw.mask * p)

        state = sampler.init()
        key = jax.random.PRNGKey(0)
        reps = 3 if n >= 1_000_000 else 10
        us = _timeit(sampler_round, state, key, reps=reps, warmup=2)
        entry = {
            "n": n, "budget": k,
            "us": us, "us_per_client": us / n,
        }
        try:
            ma = sampler_round.lower(state, key).compile().memory_analysis()
            live = int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
            )
            entry["live_bytes"] = live
            entry["bytes_per_client"] = live / n
        except Exception:
            entry["live_bytes"] = None
        row(f"fed_sampler_scale_n{n}", us,
            f"K={k} sharded sampler round (solve+draw+update)")
        entries.append(entry)
    time_flat = entries[-1]["us_per_client"] / entries[0]["us_per_client"]
    ratios = {"per_client_us_n1e6_over_n1e4": time_flat}
    derived = f"us/client N=1e4->1e6: {time_flat:.2f}x"
    if entries[0].get("live_bytes") and entries[-1].get("live_bytes"):
        bytes_flat = (
            entries[-1]["bytes_per_client"] / entries[0]["bytes_per_client"]
        )
        ratios["per_client_bytes_n1e6_over_n1e4"] = bytes_flat
        derived += f" (bytes/client: {bytes_flat:.2f}x)"
    row("fed_sampler_scale_flatness", 0, derived)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_sampler_scale.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_sampler_scale",
                "entries": entries,
                # regression-gate ratios: LOWER is better
                "ratios": ratios,
            },
            f, indent=2,
        )


# ---------------------------------------------------------------------------
# Table: fault-realism layer cost + convergence under churn
# ---------------------------------------------------------------------------


def bench_fed_fault_overhead() -> None:
    """What does deployment realism cost inside the traced round body?

    Times the deployable compiled segment (fed/server.py) for the SAME spec
    with the full fault layer on (Markov availability + deadline stragglers +
    buffered-async) vs off — the fault layer is a build-time branch, so the
    clean program is literally the pre-fault one and the ratio is the whole
    story.  Target: faulted/clean us-per-round < 1.10.  Also records
    convergence-under-churn: kvib vs uniform_isp loss curves at 30% Bernoulli
    availability (the adaptive sampler's variance edge must survive churn).
    Emits ``RESULTS/BENCH_fed_fault_overhead.json`` for the regression gate.
    """
    from repro import api
    from repro.fed import server as fed_server
    from repro.fed.state import run_segmented

    n, t_rounds = 128, 50

    def spec_with(fault, sampler="kvib", rounds=t_rounds, seed=0):
        return api.ExperimentSpec(
            task=api.TaskSpec(
                name="logreg", dataset="synthetic_classification",
                dataset_kwargs=dict(n_clients=n, total=40 * n, seed=0),
            ),
            sampler=api.SamplerSpec(
                name=sampler,
                kwargs=dict(horizon=rounds) if sampler == "kvib" else {},
            ),
            federation=api.FederationSpec(
                rounds=rounds, budget=16, local_steps=1, batch_size=8,
            ),
            execution=api.ExecutionSpec(seed=seed),
            fault=fault,
        )

    faulted_fault = api.FaultSpec(
        availability="markov",
        availability_kwargs={"p_on": 0.7, "p_off": 0.2},
        deadline=1.0, latency_kwargs={"scale": 0.5},
        async_buffer=4, staleness_discount=0.5,
    )
    goes = {}
    for mode, fault in (("clean", api.FaultSpec()), ("faulted", faulted_fault)):
        built = api.build(spec_with(fault))
        # donate=False: re-runs start from the same initial state
        segment, state0 = fed_server.build_segment_runner(
            built.task, built.dataset, built.sampler, built.fed_config, None,
            donate=False,
        )

        def go(segment=segment, state0=state0):
            out = run_segmented(state0, t_rounds, segment)
            jax.block_until_ready(out.metrics)

        goes[mode] = go
        go()  # compile up front
    # Interleaved best-of-k (the ratio is the payload; a mean would let a
    # load spike during one mode's window masquerade as fault-layer cost).
    best = {mode: float("inf") for mode in goes}
    for _ in range(8):
        for mode, go in goes.items():
            t0 = time.perf_counter()
            go()
            best[mode] = min(best[mode], time.perf_counter() - t0)
    us = {mode: b / t_rounds * 1e6 for mode, b in best.items()}
    for mode in goes:
        row(f"fed_fault_overhead_{mode}", us[mode],
            f"us/round, N={n} T={t_rounds} deployable compiled")
    ratio = us["faulted"] / us["clean"]
    row("fed_fault_overhead", 0,
        f"faulted/clean us-per-round ratio: {ratio:.3f}x (target < 1.10)")

    # Convergence under churn: 30% Bernoulli availability, adaptive vs
    # uniform — the paper's variance-reduction claim must survive churn.
    churn = api.FaultSpec(availability="bernoulli", availability_kwargs={"q": 0.3})
    curves = {}
    for sampler in ("kvib", "uniform_isp"):
        hist = api.run(spec_with(churn, sampler=sampler, rounds=40, seed=1))
        curves[sampler] = [float(x) for x in hist.train_loss]
        row(f"fed_fault_churn_{sampler}", 0,
            f"final loss @30% availability: {curves[sampler][-1]:.4f}")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_fault_overhead.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_fault_overhead",
                "entries": [{
                    "n": n, "rounds": t_rounds,
                    "clean_us_per_round": us["clean"],
                    "faulted_us_per_round": us["faulted"],
                    "churn_availability_q": 0.3,
                    "churn_loss_curves": curves,
                }],
                # regression-gate ratios: LOWER is better
                "ratios": {"faulted_over_clean_us_per_round": ratio},
            },
            f, indent=2,
        )


# ---------------------------------------------------------------------------
# Table: compressed client deltas — delta width on the zoo LM round
# ---------------------------------------------------------------------------


def bench_fed_lm_delta_width() -> None:
    """The delta-width win: int8 client deltas vs f32 on the zoo LM round.

    Three costs, one spec pair (identical except ``compression``):

    * **aggregation buffer bytes** — the HBM-resident stacked cohort buffer
      the aggregate consumes, from aval sizes (``jax.eval_shape`` over
      ``quantize_stacked``): (C, D_pad) int8 + (C, nb) f32 scales vs (C, D)
      f32.  Target: >= 3.5x smaller.
    * **us/round** — the compiled segmented scan, interleaved best-of-k (the
      quantize/dequant work must not eat the bandwidth win).
    * **checkpoint bytes** — with the buffered-async ring on, the carried
      (B, D) stale-delta buffer is quantized too, so the on-disk
      ``TrainState`` shrinks; measured from a real ``CheckpointManager``
      step directory.

    Emits ``RESULTS/BENCH_fed_lm_delta_width.json`` with lower-is-better
    int8/f32 ratios for the regression gate.
    """
    import tempfile

    from repro import api
    from repro.checkpoint import CheckpointManager
    from repro.fed.round import build_fed_scan_segment
    from repro.fed.state import run_segmented
    from repro.kernels.fused_weighted_agg import quantize_stacked
    from repro.models import transformer

    rounds, n, c = 6, 24, 6
    ring_fault = api.FaultSpec(
        async_buffer=4, staleness_discount=0.5,
        latency="exponential", latency_kwargs={"scale": 2.0},
    )

    def spec_with(compression):
        return api.ExperimentSpec(
            task=api.TaskSpec(
                kind="zoo", name="smollm-360m", reduced=True,
                kwargs=dict(
                    n_layers=2, d_model=128, d_ff=256, vocab=256,
                    round_mode="client_parallel",
                ),
                dataset="synthetic_tokens",
                dataset_kwargs=dict(
                    n_clients=n, seq_len=32, vocab=256, total_seqs=40 * n,
                    seed=0,
                ),
            ),
            sampler=api.SamplerSpec(name="kvib", kwargs=dict(horizon=rounds)),
            federation=api.FederationSpec(
                rounds=rounds, budget=c, cohort=c, local_steps=1, batch_size=8,
            ),
            execution=api.ExecutionSpec(seed=0, ckpt_every=rounds // 2),
            fault=ring_fault,
            compression=compression,
        )

    modes = {
        "f32": api.CompressionSpec(),
        "int8": api.CompressionSpec(delta_dtype="int8"),
    }
    entry: dict = {"n": n, "cohort": c, "rounds": rounds}
    goes = {}
    for mode, comp in modes.items():
        spec = spec_with(comp)
        built = api.build(spec)
        key = jax.random.PRNGKey(spec.execution.seed)
        params = transformer.init_params(built.arch_config, key)
        d_dim = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        # aggregation buffer bytes, straight from aval sizes
        if not comp.enabled:
            agg_bytes = c * d_dim * 4
        else:
            q_aval, s_aval = jax.eval_shape(
                lambda f: quantize_stacked(
                    f, dtype=comp.delta_dtype, scale_block=comp.scale_block
                ),
                jax.ShapeDtypeStruct((c, d_dim), jnp.float32),
            )
            agg_bytes = (
                q_aval.size * q_aval.dtype.itemsize
                + s_aval.size * s_aval.dtype.itemsize
            )
        entry[f"{mode}_agg_buffer_bytes"] = int(agg_bytes)
        # donate=False: the interleaved re-runs reuse the round-0 state
        segment, make_state = build_fed_scan_segment(
            built.arch_config, built.round_spec, built.sampler, built.dataset,
            donate=False,
        )
        state0 = make_state(params, built.sampler.init(), key, rounds)

        def go(segment=segment, state0=state0):
            jax.block_until_ready(run_segmented(state0, rounds, segment))

        goes[mode] = go
        go()  # compile up front
        # checkpoint bytes: a real manager step dir, async ring included
        with tempfile.TemporaryDirectory() as tmp:
            mgr = CheckpointManager(os.path.join(tmp, "ck"), keep_last=1)
            run_segmented(
                state0, rounds, segment,
                ckpt_every=spec.execution.ckpt_every, manager=mgr,
            )
            ck_bytes = sum(
                os.path.getsize(os.path.join(root, f))
                for root, _, files in os.walk(tmp)
                for f in files
            )
        entry[f"{mode}_ckpt_bytes"] = int(ck_bytes)
    best = {mode: float("inf") for mode in goes}
    for _ in range(6):
        for mode, go in goes.items():
            t0 = time.perf_counter()
            go()
            best[mode] = min(best[mode], time.perf_counter() - t0)
    for mode in goes:
        entry[f"{mode}_us_per_round"] = best[mode] / rounds * 1e6
        row(
            f"fed_lm_delta_width_{mode}", entry[f"{mode}_us_per_round"],
            f"us/round, agg buffer {entry[f'{mode}_agg_buffer_bytes']} B, "
            f"ckpt {entry[f'{mode}_ckpt_bytes']} B",
        )
    ratios = {
        "int8_over_f32_agg_buffer_bytes": entry["int8_agg_buffer_bytes"]
        / entry["f32_agg_buffer_bytes"],
        "int8_over_f32_ckpt_bytes": entry["int8_ckpt_bytes"]
        / entry["f32_ckpt_bytes"],
        "int8_over_f32_us_per_round": entry["int8_us_per_round"]
        / entry["f32_us_per_round"],
    }
    row(
        "fed_lm_delta_width", 0,
        f"agg bytes {1 / ratios['int8_over_f32_agg_buffer_bytes']:.2f}x smaller "
        f"(target >= 3.5x), ckpt {1 / ratios['int8_over_f32_ckpt_bytes']:.2f}x, "
        f"time ratio {ratios['int8_over_f32_us_per_round']:.3f}x",
    )
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_lm_delta_width.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_lm_delta_width",
                "entries": [entry],
                # regression-gate ratios: LOWER is better
                "ratios": ratios,
            },
            f, indent=2,
        )


# ---------------------------------------------------------------------------
# Paper figures from experiment artifacts
# ---------------------------------------------------------------------------


def table_synthetic() -> None:
    path = os.path.join(RESULTS, "synthetic.json")
    if not os.path.exists(path):
        row("fig2_synthetic", 0, "MISSING - run examples/synthetic_regret.py")
        return
    data = json.load(open(path))
    t = data["config"]["rounds"]
    for name, runs in data["runs"].items():
        if name == "kvib_gamma":
            continue
        reg = np.mean([r["regret"][-1] / t for r in runs])
        err = np.mean([np.mean(r["sq_error"][t // 3 :]) for r in runs])
        row(f"fig2_regretT_{name}", 0, f"dynamic regret/T={reg:.5f} est.var={err:.6f}")


def table_budget() -> None:
    path = os.path.join(RESULTS, "budget.json")
    if not os.path.exists(path):
        row("fig3b_budget", 0, "MISSING - run examples/budget_sweep.py")
        return
    data = json.load(open(path))
    for name, by_k in data["regret_per_round"].items():
        ks = sorted(by_k, key=int)
        speedup = by_k[ks[0]] / max(by_k[ks[-1]], 1e-9)
        row(
            f"fig3b_{name}",
            0,
            f"regret/T K={ks[0]}:{by_k[ks[0]]:.4f} -> K={ks[-1]}:{by_k[ks[-1]]:.4f} ({speedup:.0f}x)",
        )


def table_femnist() -> None:
    path = os.path.join(RESULTS, "femnist.json")
    if not os.path.exists(path):
        row("fig4_femnist", 0, "MISSING - run examples/femnist_style.py")
        return
    data = json.load(open(path))
    for level, lv in data["levels"].items():
        for name, run in lv["samplers"].items():
            tta = run.get("rounds_to_target")
            row(
                f"fig4_{level}_{name}",
                0,
                f"acc={run['acc'][-1]:.3f} t@target={tta} est.var={np.mean(run['sq_error']):.5f}",
            )


def table_fed_lm() -> None:
    path = os.path.join(RESULTS, "fed_lm.json")
    if not os.path.exists(path):
        row("fig5_fed_lm", 0, "MISSING - run examples/fed_lm.py")
        return
    data = json.load(open(path))
    for name, run in data["runs"].items():
        row(f"fig5_lm_{name}", 0, f"loss {run['loss'][0]:.3f}->{run['loss'][-1]:.3f}")


# ---------------------------------------------------------------------------
# Table: train-to-serve — decode throughput under checkpoint hot-swaps
# ---------------------------------------------------------------------------


def bench_fed_serve_swap() -> None:
    """Decode tokens/sec under continuous weight swaps vs a static server,
    and the paged prefill/decode split vs the old whole-sequence recompute.

    Three servers on the reduced zoo config, identical traffic:

    * **static** — ``repro.serve.ServeEngine``, one prefill + T paged decode
      steps, weights never change.
    * **swap** — the same engine geometry, but ``swap_params`` installs an
      alternating candidate every ``swap_every`` decode steps (the serving
      loop's steady state under a fast trainer; candidates pre-restored, as
      the watcher restores off the decode path).  The compile-once contract
      makes this nearly free: target swap/static us-per-token <= 1.11
      (i.e. >= 0.9x the static token rate), with the decode jit cache at
      exactly ONE entry across all swaps.
    * **recompute** — the pre-serve launcher's whole-sequence path: a full
      ``transformer.forward`` over the (B, max_seq) buffer per generated
      token (compiled once; O(S) redundant work per token vs the O(1)
      decode step).

    Emits ``RESULTS/BENCH_fed_serve_swap.json`` with both lower-is-better
    ratios for the regression gate.
    """
    from repro.configs import get_config
    from repro.models import transformer
    from repro.serve import ServeEngine

    b, plen, page, t_steps, swap_every = 4, 16, 16, 96, 16
    max_seq = plen + t_steps
    cfg = get_config("smollm-360m").reduced(
        n_layers=4, d_model=192, d_ff=512, vocab=256
    )
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = transformer.init_params(cfg, k1)
    variant = transformer.init_params(cfg, k2)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (b, plen), 0, cfg.vocab)

    engine = ServeEngine(cfg, params, batch=b, max_seq=max_seq, page_size=page)

    def run_decode(swapping: bool) -> float:
        """One full batch: prefill + t_steps decode; us per generated token."""
        if swapping:
            # Start each swapping rep from the SAME incumbent so reps are
            # identical programs (the swap itself is the measured cost).
            engine.swap_params(params)
        engine.start(prompts)
        engine.decode_tokens = 0
        engine.decode_seconds = 0.0
        done = 0
        while done < t_steps:
            done += engine.step(swap_every)
            if swapping:
                engine.swap_params(variant if done % (2 * swap_every) else params)
        return engine.decode_seconds / engine.decode_tokens * 1e6

    # The recompute server: full forward over the padded buffer per token.
    fwd = jax.jit(lambda p, toks: transformer.forward(p, cfg, toks)[0])

    def run_recompute() -> float:
        buf = jnp.zeros((b, max_seq), jnp.int32).at[:, :plen].set(prompts)
        fwd(params, buf)  # warm (compile outside the timed window)
        t0 = time.perf_counter()
        for i in range(plen, plen + t_steps):
            logits = fwd(params, buf)
            buf = buf.at[:, i].set(jnp.argmax(logits[:, i - 1], -1).astype(jnp.int32))
        jax.block_until_ready(buf)
        return (time.perf_counter() - t0) / (t_steps * b) * 1e6

    # Warm both engine entry points, then interleaved best-of-k (the ratio
    # is the payload; interleaving keeps host-load noise symmetric).
    run_decode(False)
    run_decode(True)
    best = {"static": float("inf"), "swap": float("inf"), "recompute": float("inf")}
    for _ in range(6):
        best["static"] = min(best["static"], run_decode(False))
        best["swap"] = min(best["swap"], run_decode(True))
        best["recompute"] = min(best["recompute"], run_recompute())

    cache_entries = engine.decode_cache_entries()
    assert cache_entries == 1, (
        f"decode jit cache grew to {cache_entries} under swaps (compile-once)"
    )
    assert engine.swaps >= 2, engine.swaps

    row("fed_serve_swap_static", best["static"],
        f"us/token, B={b} paged decode (page={page}), static weights")
    row("fed_serve_swap_swapping", best["swap"],
        f"us/token with a hot swap every {swap_every} steps "
        f"({engine.swaps} swaps total, {cache_entries} decode compile)")
    row("fed_serve_swap_recompute", best["recompute"],
        f"us/token, whole-sequence recompute server (S={max_seq})")
    swap_ratio = best["swap"] / best["static"]
    paged_ratio = best["static"] / best["recompute"]
    row("fed_serve_swap", 0,
        f"swap/static us-per-token ratio: {swap_ratio:.3f}x (target <= 1.11, "
        f"i.e. >= 0.9x static tokens/sec); paged/recompute: {paged_ratio:.3f}x")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_fed_serve_swap.json"), "w") as f:
        json.dump(
            {
                "bench": "fed_serve_swap",
                "entries": [{
                    "arch": cfg.name, "batch": b, "prompt_len": plen,
                    "page_size": page, "decode_steps": t_steps,
                    "swap_every": swap_every, "n_swaps": engine.swaps,
                    "decode_jit_cache_entries": cache_entries,
                    "static_us_per_token": best["static"],
                    "swap_us_per_token": best["swap"],
                    "recompute_us_per_token": best["recompute"],
                }],
                # regression-gate ratios: LOWER is better
                "ratios": {
                    "swap_over_static_us_per_token": swap_ratio,
                    "paged_over_recompute_us_per_token": paged_ratio,
                },
            },
            f, indent=2,
        )


def table_roofline() -> None:
    from repro.analysis.roofline import HW

    ddir = os.path.join(RESULTS, "dryrun")
    if not os.path.isdir(ddir):
        row("roofline", 0, "MISSING - run python -m repro.launch.dryrun --all")
        return
    hw = HW()
    for f in sorted(os.listdir(ddir)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(ddir, f)))
        if r.get("status") != "ok" or r.get("multi_pod"):
            continue
        comp = r["flops"] / hw.peak_flops
        mem = r["bytes_accessed"] / hw.hbm_bw
        coll = r["collective_bytes"] / hw.ici_bw
        dom = max((comp, "compute"), (mem, "memory"), (coll, "collective"))[1]
        row(
            f"roofline_{r['arch']}_{r['shape']}",
            0,
            f"compute={comp:.3f}s memory={mem:.3f}s collective={coll:.3f}s dominant={dom}",
        )


BENCHES = {
    "solver": bench_solver_scaling,
    "fused_agg": bench_fused_aggregation,
    "round_step": bench_round_step,
    "fed_round_scan": bench_fed_round_scan,
    "fed_scan_segmented": bench_fed_scan_segmented,
    "fed_round_cohort": bench_fed_round_cohort,
    "fed_cohort_width": bench_fed_cohort_width,
    "fed_sampler_scale": bench_fed_sampler_scale,
    "fed_fault_overhead": bench_fed_fault_overhead,
    "fed_lm_delta_width": bench_fed_lm_delta_width,
    "fed_serve_swap": bench_fed_serve_swap,
    "fig2": table_synthetic,
    "fig3b": table_budget,
    "fig4": table_femnist,
    "fig5": table_fed_lm,
    "roofline": table_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--filter", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.filter and args.filter not in name:
            continue
        fn()


if __name__ == "__main__":
    main()
