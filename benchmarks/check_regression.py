"""Benchmark regression gate over the committed ``results/BENCH_*.json``.

Every benchmark that emits a JSON artifact records a ``ratios`` dict of
dimensionless, LOWER-IS-BETTER cost ratios (e.g. deployable/oracle time, or
the N=1024/N=64 flatness of the cohort-width round).  Ratios — not absolute
microseconds — are what survive a machine change, so they are what the gate
compares: this module re-runs each such benchmark into a temporary results
dir and fails if any ratio regressed by more than ``factor`` (default 2x)
against the committed baseline.

Wired as a ``slow``-marked test (tests/test_bench_regression.py), so CI can
opt in via ``pytest -m slow`` without taxing tier-1:

  PYTHONPATH=src python -m benchmarks.check_regression [--factor 2.0]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import tempfile

DEFAULT_FACTOR = 2.0


def iter_baselines(results_dir: str = "results"):
    """Yield (bench_name, ratios) for every committed baseline with ratios."""
    for path in sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json"))):
        with open(path) as f:
            data = json.load(f)
        if data.get("ratios"):
            yield data["bench"], data["ratios"]


def check_all(results_dir: str = "results", factor: float = DEFAULT_FACTOR) -> list[str]:
    """Re-run every ratio-bearing benchmark and compare against its baseline.

    Returns a list of human-readable failure strings (empty == all within
    budget).  The re-run writes to a temp dir, so the committed baselines are
    never touched — refreshing them is an explicit ``python -m benchmarks.run``.
    """
    import benchmarks.run as bench_run

    baselines = list(iter_baselines(results_dir))
    if not baselines:
        raise FileNotFoundError(
            f"no BENCH_*.json baselines with a 'ratios' dict under {results_dir!r}"
        )
    failures = []
    old_results = bench_run.RESULTS
    with tempfile.TemporaryDirectory() as tmp:
        bench_run.RESULTS = tmp
        try:
            for name, base_ratios in baselines:
                bench_run.BENCHES[name]()
                with open(os.path.join(tmp, f"BENCH_{name}.json")) as f:
                    fresh = json.load(f)
                for key, base in base_ratios.items():
                    new = fresh["ratios"].get(key)
                    if new is None:
                        failures.append(f"{name}:{key} missing from re-run output")
                    elif new > factor * base:
                        failures.append(
                            f"{name}:{key} regressed {base:.4f} -> {new:.4f} "
                            f"(> {factor:g}x budget)"
                        )
        finally:
            bench_run.RESULTS = old_results
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.environ.get("REPRO_RESULTS", "results"))
    ap.add_argument("--factor", type=float, default=DEFAULT_FACTOR)
    args = ap.parse_args()
    failures = check_all(args.results, args.factor)
    if failures:
        print("BENCH REGRESSIONS:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("all benchmark ratios within budget")


if __name__ == "__main__":
    main()
