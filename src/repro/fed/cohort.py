"""Shared padded-cohort contract: selection, padding, and weight semantics.

Every execution substrate — the compiled single-host server loop
(``fed/server.py``), the pod-scale round step (``fed/round.py``), and the
distributed launcher (``repro.launch.train``) — consumes the SAME static
C-slot cohort representation defined here, so the unbiasedness argument is
proved once and holds everywhere.

Contract
--------
A round's ISP/RSP draw produces a stochastic included set ``S`` (the
``mask``).  ``select_cohort`` maps it onto a **static buffer of C slots**:

* ids      — (C,) int32 client indices.  The first ``min(|S|, C)`` slots (in
  random-priority order, see *overflow*) point at included clients; the
  remaining *padding* slots point at arbitrary non-included clients.
* valid    — (C,) bool, True exactly for the slots holding included clients.
  Padding slots are **inert**: their weight is zero, their feedback is zero,
  and hosts must not gather real data for them (``host_gather_cohort_batches``
  fills them with zeros; the compiled path zeroes their outputs before the
  scatter).  A padding slot therefore contributes nothing to the estimate,
  the feedback, or the loss metric — only dead static-shape compute.
* weights  — (C,) f32 estimator coefficients ``w_c = m_c lambda_c / p~_c``
  (zero on padding).  ``sum_slots w_c * delta_c`` is the unbiased estimate
  of the full-participation update (Definition 2.1).

Overflow
--------
``|S|`` is stochastic under ISP; when ``|S| > C`` the buffer cannot hold the
draw.  Selection keeps a *uniformly random* size-C subset of ``S`` (i.i.d.
uniform priorities + ``lax.top_k``) and **rescales every retained weight by
``|S|/C``** — the inverse of the acceptance probability ``C/|S|`` — so the
estimator stays unbiased:

    E[ sum_kept (|S|/C) w_i delta_i | S ] = sum_{i in S} w_i delta_i.

(The pre-fix launcher kept the original weights after dropping, which biased
the estimate low by a factor ``C/|S|`` on overflow rounds.)  Dropped clients
are reported in ``n_dropped``; they receive no feedback this round (the
server genuinely did not contact them), which the bandit samplers treat as
an observed zero — the same partial-feedback semantics as any unsampled
client.

Aggregation width
-----------------
Two aggregation consumers of a selection, with different width/equivalence
trade-offs:

* **C-width (the deployable default)** — reduce directly over the (C, ...)
  stacked cohort deltas: ``weighted_delta_sum(deltas_c, sel.weights)``, or
  ``estimator.aggregate_and_error_cohort`` when the squared-error diagnostic
  should ride along.  O(C*D) compute and memory; nothing (N, D)-shaped ever
  exists (tests assert this on the round body's jaxpr).  Because the
  reduction runs over C terms instead of N, partial-sum order differs from
  the full-mask contraction: the result equals the N-width one in *exact*
  arithmetic but only to float tolerance on hardware (allclose, not
  bitwise).
* **N-width scatter (``FedConfig.exact_oracle_equiv=True``)** —
  ``scatter_cohort`` the deltas/weights back to (N, ...) zero-padded buffers
  and reuse the oracle path's contraction.  Inserted zero terms cannot change
  the reduction's partial sums, so when ``|S| <= C`` the round is **bitwise**
  identical to the full-mask round — the property the cross-mode equality
  tests pin down — at O(N*D) memory cost.

Everything else in the round is width-honest either way: sampler feedback and
state are legitimately (N,)-vectors (scatters of (C,) values), train-loss is
a (C,)-reduction.

A third, orthogonal axis is the *delta width* (``CompressionSpec``): the
C-width stacked buffer may be held at int8/fp8 instead of f32, with one fp32
abs-max scale per (slot, block) and dequantization fused into the aggregate
(``estimator.aggregate_compressed`` /
``kernels.fused_dequant_cohort_agg``).  The contract stays C-width — nothing
(N, D)-shaped appears — but the equivalence weakens one more notch: the
compressed aggregate matches the f32 C-width one only to quantization
tolerance, with the server-side error-feedback residual restoring the
*trajectory* (not the per-round aggregate) to f32-allclose.  Because the
deployable compressed round can no longer reproduce the oracle contraction,
``exact_oracle_equiv`` + compression raises at build time.  Sampler feedback
remains width-honest: the (C,) norms the samplers consume are computed from
the *dequantized* deltas, i.e. the same values the estimate actually used.

Determinism
-----------
When ``|S| <= C`` the selection keeps *all* of ``S`` with weights bitwise
equal to the full-mask weights (rescale is exactly 1.0), so a cohort-only
round under the N-width scatter reproduces the full-mask round bit-for-bit,
and under C-width aggregation to float tolerance (tests/test_scan_server.py).
All functions are shape-static and trace-safe (usable inside ``lax.scan``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CohortSelection",
    "select_cohort",
    "mask_selection",
    "scatter_cohort",
    "weighted_delta_sum",
    "host_gather_cohort_batches",
]


class CohortSelection(NamedTuple):
    """Static C-slot cohort (see module docstring for the full contract)."""

    ids: jax.Array  # (C,) int32 client index per slot
    weights: jax.Array  # (C,) f32 estimator weight per slot (0 on padding)
    valid: jax.Array  # (C,) bool slot holds an included client
    n_included: jax.Array  # scalar int32 |S| (pre-overflow)
    n_dropped: jax.Array  # scalar int32 max(|S| - C, 0)


def select_cohort(
    mask: jax.Array, weights: jax.Array, cohort: int, key: jax.Array
) -> CohortSelection:
    """Map an (N,) inclusion mask + full weight vector onto C static slots.

    ``lax.top_k`` over i.i.d. uniform priorities (masked-out clients get -1)
    keeps all of S when ``|S| <= C`` and a uniformly random size-C subset on
    overflow, with retained weights rescaled by ``|S|/C`` (unbiased; module
    docstring).  Scan/jit-safe: ``cohort`` must be a static Python int.
    """
    n = mask.shape[0]
    c = int(min(int(cohort), n))
    priority = jnp.where(mask, jax.random.uniform(key, (n,)), -1.0)
    _, ids = jax.lax.top_k(priority, c)
    ids = ids.astype(jnp.int32)
    valid = mask[ids]
    n_inc = jnp.sum(mask.astype(jnp.int32))
    # rescale == exactly 1.0 when there is no overflow (x * 1.0 is bitwise x),
    # so the no-overflow cohort weights match the full-mask weights exactly.
    rescale = jnp.where(n_inc > c, n_inc.astype(jnp.float32) / c, 1.0)
    w = jnp.where(valid, weights[ids].astype(jnp.float32) * rescale, 0.0)
    n_kept = jnp.sum(valid.astype(jnp.int32))
    return CohortSelection(
        ids=ids, weights=w, valid=valid, n_included=n_inc, n_dropped=n_inc - n_kept
    )


def mask_selection(
    sel: CohortSelection, keep: jax.Array, rescale: float | jax.Array = 1.0
) -> CohortSelection:
    """Demote slots with ``keep == False`` to inert padding, post-selection.

    The deadline-straggler hook (``core.stragglers``): clients past the round
    deadline are masked out of the cohort AFTER local training was scheduled
    — their (C,)-slot compute already happened, but the slot's weight,
    validity, and hence feedback and loss contribution are zeroed exactly
    like the inert-padding contract above, so the O(C*D) aggregation path is
    untouched.  Survivors' weights are multiplied by ``rescale`` (the
    ``1 / P(latency <= deadline)`` unbiasedness correction — a static float,
    so ``rescale == 1.0`` keeps the weights bitwise).  Newly-dropped slots
    are accounted in ``n_dropped``.
    """
    valid = jnp.logical_and(sel.valid, keep)
    w = jnp.where(
        valid, sel.weights * jnp.asarray(rescale, sel.weights.dtype), 0.0
    )
    n_kept = jnp.sum(valid.astype(jnp.int32))
    return CohortSelection(
        ids=sel.ids,
        weights=w,
        valid=valid,
        n_included=sel.n_included,
        n_dropped=sel.n_included - n_kept,
    )


def scatter_cohort(values, sel: CohortSelection, n: int):
    """(C, ...)-stacked pytree -> (N, ...) with zeros for non-cohort clients.

    Padding slots are zeroed before the scatter (inert contract), so a padded
    slot aliasing a real client's index cannot corrupt that client's row.
    Slot ids from ``select_cohort`` are distinct, so ``add`` never collides on
    valid rows and the scattered values are bitwise the slot values.
    """

    def one(leaf):
        keep = sel.valid.reshape((-1,) + (1,) * (leaf.ndim - 1))
        v = jnp.where(keep, leaf, jnp.zeros((), leaf.dtype))
        return jnp.zeros((n,) + leaf.shape[1:], leaf.dtype).at[sel.ids].add(v)

    return jax.tree_util.tree_map(one, values)


def weighted_delta_sum(deltas, w: jax.Array):
    """``sum_c w_c * delta_c`` over a stacked (C, ...) pytree, f32 accumulate.

    The single aggregation primitive of the padded-cohort contract: with
    ``w`` from ``select_cohort`` this is the unbiased estimate ``d^t``; with
    ``w = lambda`` it is the full-participation target.
    """

    def one(leaf):
        wc = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(wc * leaf.astype(jnp.float32), axis=0)

    return jax.tree_util.tree_map(one, deltas)


@functools.lru_cache(maxsize=32)
def _zero_block(shape: tuple, dtype_name: str) -> np.ndarray:
    """Shared all-zero padding buffer, allocated once per (shape, dtype) for
    the process lifetime.  Callers treat it as read-only (every consumer
    copies on ``np.stack``), so one buffer serves every round and every
    padding slot — the pre-hoist code re-allocated both buffers each call."""
    return np.zeros(shape, np.dtype(dtype_name))


def host_gather_cohort_batches(
    dataset, sel: CohortSelection, k_data: jax.Array, local_steps: int, batch_size: int
):
    """Host-side padded batch gather for the launcher: (C, R, B, ...) buffers.

    Valid slots gather their client's R local batches (keys derived by
    ``fold_in(k_data, client_id)`` so the stream is slot-order independent);
    padding slots are all-zero and cost no gather — the inert-padding
    contract (their weight is zero, so the zeros never reach the estimate).
    """
    ids = np.asarray(sel.ids)
    valid = np.asarray(sel.valid)
    zero_feat = _zero_block(
        (local_steps, batch_size) + tuple(dataset.features.shape[2:]),
        str(dataset.features.dtype),
    )
    zero_lab = _zero_block(
        (local_steps, batch_size) + tuple(dataset.labels.shape[2:]),
        str(dataset.labels.dtype),
    )
    feats, labs = [], []
    for slot in range(len(ids)):
        if not valid[slot]:
            feats.append(zero_feat)
            labs.append(zero_lab)
            continue
        cid = int(ids[slot])
        keys = jax.random.split(jax.random.fold_in(k_data, cid), local_steps)
        batches = [dataset.client_batch(cid, kr, batch_size) for kr in keys]
        feats.append(np.stack([np.asarray(f) for f, _ in batches]))
        labs.append(np.stack([np.asarray(l) for _, l in batches]))
    return jnp.asarray(np.stack(feats)), jnp.asarray(np.stack(labs))
