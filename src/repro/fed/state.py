"""Canonical compiled-horizon carry (``TrainState``) + the segmented driver.

The K-Vib sampler's value is its *online* state: the cumulative feedback it
accumulates over the horizon is what drives the variance-reduced regret bound
(PAPER.md section 4), so a preempted server that loses sampler state loses the
learned sampling probabilities, not just wall-clock.  This module is the
preemption-safety layer for the compiled execution paths: instead of running
the whole horizon as one opaque ``lax.scan``, the horizon is cut into jitted
scan *segments* of ``ckpt_every`` rounds driven from a host loop that can
publish a checkpoint (``repro.checkpoint.CheckpointManager``) at every
segment boundary.

What must be in the carry
-------------------------

``TrainState`` is the single canonical pytree that round-trips through
segment boundaries AND through checkpoints.  Everything a resumed process
needs to continue the run bit-for-bit must live here as an *array* leaf:

* ``params``     — model parameters (pytree of arrays).
* ``opt_state``  — server-optimizer state (``()`` for stateless FedAvg).
* ``sampler``    — the sampler's online state (``core.samplers.SamplerState``
                   contract: flat pytree of arrays, no Python scalars).
* ``metrics``    — dict of on-device ``(T, ...)`` per-round metric buffers,
                   preallocated for the FULL horizon and stitched segment by
                   segment via ``lax.dynamic_update_slice`` — a resumed run's
                   ``History`` therefore covers the whole horizon, including
                   rounds executed before the preemption.
* ``round``      — scalar int32: the next round to execute (also the write
                   offset into the metric buffers and the checkpoint step).
* ``key``        — the PRNG key from which the remaining rounds' per-round
                   keys derive.  Each segment advances it by exactly
                   ``n_rounds`` chained splits, so any segmentation of the
                   horizon consumes the identical key stream.
* ``faults``     — the fault layer's carried state when a
                   ``repro.api.FaultSpec`` is enabled (Markov availability
                   chain, buffered-async stale-delta ring —
                   ``core.stragglers.fault_state_init``); ``()`` otherwise.
                   Living here is what makes a SIGKILL'd faulted run resume
                   bit-for-bit and keeps async segmentation bitwise-neutral
                   (pending deltas ride the boundary instead of flushing).
* ``compression``— the delta-compression layer's carried state when a
                   ``repro.api.CompressionSpec`` with error feedback is
                   enabled: ``{"resid": (D,) f32}``, the server-side
                   error-feedback residual.  Riding the carry keeps the
                   quantization-error telescope exact across segment
                   boundaries, SIGKILL/resume, and mesh re-shapes;
                   ``()`` otherwise.

Segmentation is a pure reshaping of the horizon: for any ``ckpt_every`` the
per-round bodies see the same carries, keys, and round indices, so results
are bitwise identical to the monolithic scan (tests/test_segmented_scan.py
pins this at ``ckpt_every`` in {1, 7, T}).

Restore is template-shaped: build the fresh round-0 state, then refill it
from the checkpoint.  ``repro.api.restore_template(spec)`` constructs that
template for either stack straight from the declarative
``repro.api.ExperimentSpec`` — the same spec whose
``config_fingerprint(spec.to_dict())`` guards the manifest.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "TrainState",
    "build_placement",
    "make_segment_fn",
    "init_metric_buffers",
    "run_segmented",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    """The canonical compiled-horizon carry (see module docstring)."""

    params: Any
    opt_state: Any
    sampler: Any
    metrics: Any
    round: jax.Array  # scalar int32 — next round to execute
    key: jax.Array  # PRNG key for the remaining rounds' key derivation
    faults: Any = ()  # fault-layer carry (FaultSpec enabled) or ()
    compression: Any = ()  # error-feedback residual carry (CompressionSpec) or ()

    def tree_flatten(self):
        children = (
            self.params,
            self.opt_state,
            self.sampler,
            self.metrics,
            self.round,
            self.key,
            self.faults,
            self.compression,
        )
        return children, None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def init_metric_buffers(body, carry, xs_example, total_rounds: int):
    """Zero-preallocated full-horizon ``(T, ...)`` metric buffers, shaped by
    ``jax.eval_shape`` of the round body's per-round metrics output — the
    buffers a segment stitches into at offset ``state.round``."""
    _, metric_shapes = jax.eval_shape(body, carry, xs_example)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((int(total_rounds),) + s.shape, s.dtype), metric_shapes
    )


def build_placement(template: TrainState, sampler) -> TrainState:
    """Canonical ``TrainState`` device-placement pytree for a mesh-sharded
    sampler, handed to ``make_segment_fn(placement=...)``.

    ``template`` only needs shapes/dtypes — concrete arrays and
    ``ShapeDtypeStruct`` pytrees both work.  Rule: sampler-state leaves with
    a leading (N,) axis live split along ``sampler.shard``'s mesh axis;
    metric buffers with a trailing (N,) axis (the oracle score history)
    split that axis the same way; every other leaf — params, optimizer
    state, scalar metrics, round counter, key — is explicitly replicated.
    Making the whole carry's placement explicit (not just the sharded
    leaves) is what keeps the jit cache at one entry: fresh states, carried
    outputs, and numpy-round-tripped restores all ``device_put`` onto this
    exact layout before entering the jitted segment.

    When N is not divisible by the shard count, the at-rest placement falls
    back to replicated for the affected leaves — ``device_put`` cannot
    express an uneven split, while the in-trace sharding constraints can
    (GSPMD pads internally), so compute stays sharded either way."""
    shard = sampler.shard
    mesh = shard.mesh()
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    row = shard.named_sharding(mesh)
    n = sampler.n
    divisible = n % shard.num_shards == 0

    def sampler_rule(leaf):
        if divisible and leaf.ndim >= 1 and leaf.shape[0] == n:
            return row
        return rep

    def metric_rule(leaf):
        if divisible and leaf.ndim >= 2 and leaf.shape[-1] == n:
            spec = jax.sharding.PartitionSpec(
                *([None] * (leaf.ndim - 1)), shard.axis
            )
            return jax.sharding.NamedSharding(mesh, spec)
        return rep

    return TrainState(
        params=jax.tree_util.tree_map(lambda _: rep, template.params),
        opt_state=jax.tree_util.tree_map(lambda _: rep, template.opt_state),
        sampler=jax.tree_util.tree_map(sampler_rule, template.sampler),
        metrics=jax.tree_util.tree_map(metric_rule, template.metrics),
        round=rep,
        key=rep,
        # The fault carry follows the sampler rule: the (N,) Markov
        # availability chain lives split along the sampler's mesh axis, the
        # (B, D) stale-delta buffer (B != N) falls through to replicated.
        faults=jax.tree_util.tree_map(sampler_rule, template.faults),
        # The error-feedback residual is (D,)-shaped — D could coincidentally
        # equal N, so it gets an explicit replicated rule, not sampler_rule.
        compression=jax.tree_util.tree_map(lambda _: rep, template.compression),
    )


def make_segment_fn(
    body,
    derive_step,
    *,
    with_opt_state: bool,
    with_round_index: bool,
    with_faults: bool = False,
    with_compression: bool = False,
    donate: bool = True,
    placement=None,
):
    """The ONE implementation of a jitted scan segment over ``TrainState``.

    Both compiled paths — ``fed.server.build_segment_runner`` and
    ``fed.round.build_fed_scan_segment`` — get their segment function here,
    so the bitwise-neutrality contract (key-chain advance, metric-buffer
    stitch offset, round accounting, donation gating) lives in exactly one
    place.  The returned ``segment(state, n_rounds)`` (jitted, ``n_rounds``
    static):

    1. derives the next ``n_rounds`` key pairs by scanning ``derive_step``
       (one chained-split link, returning ``(key, stacked pair)``) from
       ``state.key``;
    2. scans ``body`` over them — carry ``(params, opt_state, sampler)``
       when ``with_opt_state`` else ``(params, sampler)``, with
       ``state.faults`` appended as a trailing carry element when
       ``with_faults`` (the fault layer's availability chain / stale-delta
       buffer advance inside the scan exactly like the sampler state), and
       ``state.compression`` (the error-feedback residual) appended after
       it when ``with_compression``; xs
       ``(ts, pairs[:, 0], pairs[:, 1])`` with ``ts = round + arange`` when
       ``with_round_index`` else the raw ``pairs``;
    3. stitches the stacked per-round metrics into the full-horizon buffers
       at offset ``state.round`` via ``dynamic_update_slice``;
    4. returns the advanced ``TrainState`` (``round + n_rounds``, new key).

    ``donate=False`` keeps the input state alive across calls (benchmarks
    re-time from the same state; donation would invalidate it on non-CPU
    backends — the CPU backend never donates).

    ``placement`` (a pytree of ``Sharding``s matching ``TrainState``, built
    by the caller when the sampler's (N,) axis is mesh-sharded) makes the
    carry's device layout canonical at the host boundary: every call first
    ``device_put``s the state to that placement.  Without it, the first call
    (uncommitted fresh state) and every later call (committed outputs
    carrying the in-body sharding constraints) present different input
    shardings to the jit cache and the second call pays a full recompile —
    with it, fresh states, carried states, and numpy-round-tripped restores
    all hit the single compiled entry (the compile-once contract,
    ``analysis.lint.audit_compile_once``).  Re-placing an already-placed
    carry is a no-op dispatch, not a copy.

    The stitch offset into the ``(T, ...)`` metric buffers is
    ``round mod T_buf`` — identity for full-horizon buffers (``round < T``,
    so this stays bitwise-neutral), a ring write for shorter host-offload
    buffers (``fed.server`` score-history offload allocates
    ``(ckpt_every, N)`` and drains to host every segment).
    """
    donate_argnums = (0,) if donate and jax.default_backend() != "cpu" else ()

    @functools.partial(jax.jit, static_argnums=(1,), donate_argnums=donate_argnums)
    def segment(state: TrainState, n_rounds: int) -> TrainState:
        key, pairs = jax.lax.scan(derive_step, state.key, None, length=n_rounds)
        if with_opt_state:
            carry = (state.params, state.opt_state, state.sampler)
        else:
            carry = (state.params, state.sampler)
        if with_faults:
            carry = carry + (state.faults,)
        if with_compression:
            carry = carry + (state.compression,)
        if with_round_index:
            ts = state.round + jnp.arange(n_rounds, dtype=jnp.int32)
            xs = (ts, pairs[:, 0], pairs[:, 1])
        else:
            xs = pairs
        carry, stacked = jax.lax.scan(body, carry, xs)
        if with_compression:
            carry, c_state = carry[:-1], carry[-1]
        else:
            c_state = state.compression
        if with_faults:
            carry, f_state = carry[:-1], carry[-1]
        else:
            f_state = state.faults
        if with_opt_state:
            params, opt_state, s_state = carry
        else:
            (params, s_state), opt_state = carry, state.opt_state
        metrics = jax.tree_util.tree_map(
            lambda buf, seg: jax.lax.dynamic_update_slice(
                buf,
                seg,
                (jax.lax.rem(state.round, jnp.int32(buf.shape[0])),)
                + (0,) * (buf.ndim - 1),
            ),
            state.metrics,
            stacked,
        )
        return TrainState(
            params=params,
            opt_state=opt_state,
            sampler=s_state,
            metrics=metrics,
            round=state.round + n_rounds,
            key=key,
            faults=f_state,
            compression=c_state,
        )

    lint_info = {
        "body": body,
        "derive_step": derive_step,
        "with_opt_state": with_opt_state,
        "with_round_index": with_round_index,
        "with_faults": with_faults,
        "with_compression": with_compression,
        "donate": donate,
        "donate_argnums": donate_argnums,
        "placement": placement,
    }

    if placement is not None:
        jitted = segment

        def segment(state: TrainState, n_rounds: int) -> TrainState:
            return jitted(jax.device_put(state, placement), n_rounds)

        segment._cache_size = jitted._cache_size

    # Lintable handles for the static checkers (repro.analysis.lint):
    # audit_compile_once reads the declared donation setup from here and the
    # jit cache counter from the PjitFunction itself, so the compile-once /
    # donation contract is checkable without re-deriving how the segment was
    # built.
    segment._lint = lint_info
    return segment


def run_segmented(
    state: TrainState,
    total_rounds: int,
    segment_fn: Callable[[TrainState, int], TrainState],
    *,
    ckpt_every: int = 0,
    manager=None,
    on_segment: Callable[[TrainState, int], None] | None = None,
    max_segments: int | None = None,
    publish: Callable[[TrainState, int], None] | None = None,
) -> TrainState:
    """Host-driven loop over jitted scan segments of ``ckpt_every`` rounds.

    Starts from ``state.round`` (0 for a fresh state, later for one restored
    from a checkpoint) and calls ``segment_fn(state, n_rounds)`` — a function
    jitted with a *static* segment length — until ``total_rounds`` is reached.
    ``ckpt_every <= 0`` runs the remainder as ONE segment (the monolithic
    scan, now merely the degenerate segmentation).

    After each segment, in order: ``manager.save(state, step=rounds_done)``
    publishes a checkpoint (atomic npz + manifest — the manifest write is the
    commit point), then ``publish(state, rounds_done)`` announces the
    boundary, then ``on_segment(state, rounds_done)`` runs (progress
    printing, cooperative-preemption hooks).  ``max_segments`` stops the loop
    early after that many segments — cooperative preemption for time-limited
    schedulers, and what the resume tests use to simulate a mid-horizon kill.

    ``publish`` is the train side of the train-to-serve loop
    (``repro.serve``): because it fires strictly AFTER the manifest commit,
    a serving process notified at (or polling around) that moment is
    guaranteed to observe the step via ``CheckpointManager.wait_for_next`` —
    the hook requires ``manager`` (without one there is no committed
    artifact to announce).

    Returns the final (or preempted) state; ``int(state.round)`` tells the
    caller how far it got.
    """
    if publish is not None and manager is None:
        raise ValueError(
            "run_segmented(publish=...) requires a manager: the publish hook "
            "announces COMMITTED checkpoint boundaries, and only the "
            "manager's manifest write commits one"
        )
    done = int(state.round)
    if done > total_rounds:
        raise ValueError(
            f"state.round={done} is past the horizon total_rounds={total_rounds}"
        )
    seg = int(ckpt_every) if ckpt_every and ckpt_every > 0 else int(total_rounds)
    n_segments = 0
    while done < total_rounds:
        n = min(seg, total_rounds - done)
        state = segment_fn(state, n)
        done += n
        if manager is not None:
            manager.save(state, step=done)
            if publish is not None:
                publish(state, done)
        if on_segment is not None:
            on_segment(state, done)
        n_segments += 1
        if max_segments is not None and n_segments >= max_segments:
            break
    return state
