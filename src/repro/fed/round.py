"""Pod-scale federated round steps (the distributed Algorithm 1).

Two cohort execution modes (DESIGN.md section 3):

* client_parallel — cohort members vmapped across the batch ('data'/'pod')
  mesh axes; per-client diverged params live concurrently (C copies, each
  tensor-sharded over 'model').  Round latency ~= one client's local run.
* cohort_sequential — lax.scan over cohort members; each member's batch is
  itself data-parallel and params are FSDP-sharded over (batch x model)
  axes; only ONE diverged copy + the accumulator exist at a time, which is
  what lets llama3-405b / arctic-480b run true R-step local training.

Both produce:
  new_params  — x^{t+1} = x^t - eta_g * d^t with the unbiased ISP estimate
                d^t = sum_c w_c * (x^t - x_c^{t,R}),  w_c = m_c lambda_c / p~_c
  feedback    — pi_t(c) = ||delta_c||  (weights applied by the server, which
                knows lambda; the norm rides the aggregation pass)
  mean loss over the active (w != 0) cohort slots — padding is inert.

The round consumes a *static padded cohort* of size C with the inclusion
mask folded into w (w_c = 0 for padding) — ISP's stochastic |S^t| maps onto
fixed TPU shapes this way.  Selection/padding/weight semantics live in
``repro.fed.cohort`` (the shared contract with the compiled server loop and
the launcher); this module is the device-side consumer of that contract.

``RoundSpec`` is this stack's low-level knob set; the canonical experiment
description is ``repro.api.ExperimentSpec``, whose zoo dispatch
(``repro.api.run`` / ``repro.launch.train``) projects its ``FederationSpec``
onto a ``RoundSpec`` and drives ``build_fed_scan_segment``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.fed.cohort import mask_selection, select_cohort, weighted_delta_sum
from repro.fed.state import (
    TrainState,
    build_placement,
    init_metric_buffers,
    make_segment_fn,
)
from repro.models import transformer
from repro.models.common import ArchConfig

__all__ = [
    "RoundSpec",
    "build_round_step",
    "build_fed_scan",
    "build_fed_scan_segment",
    "scan_body_for_lint",
]


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    cohort: int  # padded cohort size C
    local_steps: int  # R
    local_lr: float = 0.02
    server_lr: float = 1.0
    local_batch: int = 2  # B_local (used by the compiled scan's device gather)
    # Deployment-realism fault layer (a ``repro.api.FaultSpec`` or None —
    # see ``FedConfig.faults``).  None builds the exact pre-fault scan body;
    # enabled faults require the segment-shaped runner
    # (``build_fed_scan_segment``) — the monolithic ``build_fed_scan`` and
    # the host launcher loop raise.
    faults: object | None = None
    # Delta-width compression (a ``repro.api.CompressionSpec`` or None — see
    # ``FedConfig.compression``).  None builds the exact pre-compression scan
    # body.  Only ``client_parallel`` supports it (cohort_sequential never
    # materializes a (C, D) stacked buffer to compress); enabled compression
    # requires the segment-shaped runner, like faults.
    compression: object | None = None


def _tree_sq_norm(delta):
    sq = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), delta
    )
    return jax.tree_util.tree_reduce(jnp.add, sq)


def _local_train(params, cfg: ArchConfig, batches, lr: float):
    """R local SGD steps on one client. batches: pytree with leading R axis.

    Returns (delta = x0 - xR, last-step loss)."""

    def step(p, batch):
        loss, grads = jax.value_and_grad(lambda q: transformer.loss_fn(q, cfg, batch))(p)
        p = jax.tree_util.tree_map(lambda w, g: w - lr * g.astype(w.dtype), p, grads)
        return p, loss

    final, losses = jax.lax.scan(step, params, batches)
    delta = jax.tree_util.tree_map(lambda a, b: (a - b).astype(a.dtype), params, final)
    return delta, losses[-1]


def build_round_step(cfg: ArchConfig, spec: RoundSpec, constrain=None) -> Callable:
    """Returns round_step(params, tokens, targets, weights[, aux_embeds]).

    tokens/targets: (C, R, B_local, S) int32 — each cohort member's R local
    batches.  aux_embeds (multimodal archs): (C, R, B_local, S_front, F).
    weights: (C,) f32 — m_c * lambda_c / p~_c (zero for cohort padding).
    constrain: optional fn(param-like pytree) -> pytree applying sharding
    constraints — REQUIRED at scale for cohort_sequential so the f32
    estimate accumulator stays FSDP-sharded instead of being replicated and
    all-reduced every cohort step (EXPERIMENTS.md section Perf, qwen3 iter 1).
    """
    mode = cfg.round_mode
    if constrain is None:
        constrain = lambda tree: tree
    comp = spec.compression
    comp_on = comp is not None
    if comp_on and mode != "client_parallel":
        raise ValueError(
            f"RoundSpec.compression needs round_mode='client_parallel' (got "
            f"{mode!r}): cohort_sequential accumulates per-member deltas one "
            "at a time and never materializes the (C, D) stacked buffer that "
            "delta-width compression shrinks"
        )

    def per_client(params, tok, tgt, aux):
        batches = (tok, tgt) if aux is None else (tok, tgt, aux)
        delta, loss = _local_train(params, cfg, batches, spec.local_lr)
        return delta, loss, jnp.sqrt(_tree_sq_norm(delta))

    def cohort_mean_loss(losses, weights):
        # Padding slots (w == 0) hold inert all-zero batches; their loss is
        # meaningless and must not pollute the round's reported loss.
        active = weights != 0.0
        return jnp.sum(jnp.where(active, losses, 0.0)) / jnp.maximum(
            jnp.sum(active.astype(jnp.float32)), 1.0
        )

    if mode == "client_parallel":
        if comp_on:
            from repro.core import estimator

            def round_step(
                params, tokens, targets, weights, aux_embeds=None, resid=None
            ):
                def one(tok, tgt, aux):
                    return per_client(params, tok, tgt, aux)

                if aux_embeds is None:
                    deltas, losses, _ = jax.vmap(
                        lambda tok, tgt: one(tok, tgt, None)
                    )(tokens, targets)
                else:
                    deltas, losses, _ = jax.vmap(one)(tokens, targets, aux_embeds)
                # Compressed aggregation: the stacked cohort deltas are
                # quantized and reduced by the fused dequant kernel; passing
                # ``weights`` for lam_cohort zeroes the (unused here) error
                # row.  Feedback norms come from the dequantized values.
                d, _, norms, new_resid = estimator.aggregate_compressed(
                    deltas, weights, weights, comp, resid
                )
                new_params = jax.tree_util.tree_map(
                    lambda p, g: p - spec.server_lr * g.astype(p.dtype), params, d
                )
                return new_params, norms, cohort_mean_loss(losses, weights), new_resid

            return round_step

        def round_step(params, tokens, targets, weights, aux_embeds=None):
            def one(tok, tgt, aux):
                return per_client(params, tok, tgt, aux)

            if aux_embeds is None:
                deltas, losses, norms = jax.vmap(
                    lambda tok, tgt: one(tok, tgt, None)
                )(tokens, targets)
            else:
                deltas, losses, norms = jax.vmap(one)(tokens, targets, aux_embeds)
            d = weighted_delta_sum(deltas, weights)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - spec.server_lr * g.astype(p.dtype), params, d
            )
            return new_params, norms, cohort_mean_loss(losses, weights)

        return round_step

    if mode == "cohort_sequential":

        def round_step(params, tokens, targets, weights, aux_embeds=None):
            acc0 = constrain(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
            )

            def body(acc, inp):
                if aux_embeds is None:
                    tok, tgt, w = inp
                    aux = None
                else:
                    tok, tgt, w, aux = inp
                delta, loss, norm = per_client(params, tok, tgt, aux)
                delta = constrain(delta)
                acc = jax.tree_util.tree_map(
                    lambda a, dl: a + w * dl.astype(jnp.float32), acc, delta
                )
                return constrain(acc), (loss, norm)

            xs = (
                (tokens, targets, weights)
                if aux_embeds is None
                else (tokens, targets, weights, aux_embeds)
            )
            d, (losses, norms) = jax.lax.scan(body, acc0, xs)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - spec.server_lr * g.astype(p.dtype), params, d
            )
            return new_params, norms, cohort_mean_loss(losses, weights)

        return round_step

    raise ValueError(f"unknown round_mode {mode!r}")


def build_fed_scan(
    cfg: ArchConfig,
    spec: RoundSpec,
    sampler,
    dataset,
    *,
    mesh=None,
    constrain=None,
) -> Callable:
    """Compiled multi-round federated training: ONE jitted ``lax.scan`` whose
    per-round body is this module's pod-scale ``build_round_step`` — the
    mesh-parallel counterpart of the single-host scan loop in ``fed/server.py``
    and the compiled form of the ``repro.launch.train`` host loop.

    Per round, entirely inside the trace: probabilities solved once, ISP/RSP
    draw, padded-cohort selection (shared ``fed.cohort`` contract, unbiased
    |S|/C overflow rescaling), device-side cohort batch gather (keys derived
    by ``fold_in(k_data, client_id)`` — the identical stream to
    ``host_gather_cohort_batches``, so the compiled and host loops train on
    the same batches), the round step's local training + cohort-width
    aggregation, feedback scatter, sampler update.  Every buffer with a
    parameter axis is C-wide; the sampler state and feedback are the only
    N-sized tensors, and they are (N,)-vectors.

    With ``mesh`` (from ``repro.launch.mesh``), cohort batches carry sharding
    constraints: client_parallel spreads the C cohort members across the
    mesh's batch axes, cohort_sequential spreads each member's local batch —
    one dispatch drives the whole sharded multi-round run.

    Returns ``run(params, s_state, round_keys)`` with ``round_keys`` (T, 2, 2)
    stacked (k_draw, k_data) pairs; yields (params, s_state, metrics) where
    metrics are (T,)-stacked ``loss`` / ``cohort_size`` / ``dropped``.

    For the preemption-safe segment-shaped form of the same computation, see
    ``build_fed_scan_segment``.
    """
    if spec.faults is not None:
        raise ValueError(
            "RoundSpec.faults requires the segment-shaped runner "
            "(build_fed_scan_segment): the fault state (availability chain, "
            "stale-delta buffer) lives in the TrainState carry, which the "
            "monolithic build_fed_scan signature cannot thread"
        )
    if spec.compression is not None:
        raise ValueError(
            "RoundSpec.compression requires the segment-shaped runner "
            "(build_fed_scan_segment): the error-feedback residual lives in "
            "the TrainState carry, which the monolithic build_fed_scan "
            "signature cannot thread"
        )
    body = _build_scan_body(cfg, spec, sampler, dataset, mesh, constrain)

    donate = (0,) if jax.default_backend() != "cpu" else ()

    @functools.partial(jax.jit, donate_argnums=donate)
    def run(params, s_state, round_keys):
        (params, s_state), metrics = jax.lax.scan(
            body, (params, s_state), round_keys
        )
        return params, s_state, metrics

    return run


def _build_scan_body(cfg, spec, sampler, dataset, mesh, constrain):
    """The per-round scan body shared by ``build_fed_scan`` (monolithic) and
    ``build_fed_scan_segment``: (params, s_state) carry, (2, key) xs.

    With ``spec.faults`` set the body grows the deployment-realism layer
    (``repro.core.stragglers``; same semantics as the simulation stack's
    ``fed.server._build_round_body``): carry becomes
    ``(params, s_state, f_state)`` and xs ``(t, k_draw, k_data)`` — the round
    index feeds the availability process and the async ring."""
    from repro.core import estimator, stragglers

    lam = dataset.lam
    n = dataset.n_clients
    round_step = build_round_step(cfg, spec, constrain)

    faults = spec.faults
    fault_on = faults is not None
    avail_on = fault_on and faults.availability is not None
    deadline_on = fault_on and faults.deadline is not None
    async_on = fault_on and int(faults.async_buffer) > 0
    surv = stragglers.deadline_survival(faults) if deadline_on else 1.0
    comp = spec.compression
    comp_on = comp is not None
    ef_on = comp_on and bool(comp.error_feedback)

    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        from repro.launch.mesh import batch_axes

        baxes = batch_axes(mesh)
        # (C, R, B, S) batches: client_parallel shards cohort members,
        # cohort_sequential scans members and shards their local batch.
        spec_nd = (
            PartitionSpec(baxes)
            if cfg.round_mode == "client_parallel"
            else PartitionSpec(None, None, baxes)
        )

        def shard_batches(x):
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec_nd))

    else:

        def shard_batches(x):
            return x

    def gather_cohort(sel, k_data):
        """(C, R, B, ...) device gather; padding slots zeroed (inert)."""

        def one(cid):
            keys = jax.random.split(
                jax.random.fold_in(k_data, cid), spec.local_steps
            )
            return jax.vmap(
                lambda kr: dataset.client_batch(cid, kr, spec.local_batch)
            )(keys)

        feats, labs = jax.vmap(one)(sel.ids)

        def zero_pad(leaf):
            keep = sel.valid.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return jnp.where(keep, leaf, jnp.zeros((), leaf.dtype))

        return shard_batches(zero_pad(feats)), shard_batches(zero_pad(labs))

    def body(carry, xs):
        c_state = {}
        if ef_on:
            carry, c_state = carry[:-1], carry[-1]
        if fault_on:
            params, s_state, f_state = carry
            t, k_draw, k_data = xs
        else:
            params, s_state = carry
            f_state = {}
            t = None
            k_draw, k_data = xs[0], xs[1]
        p = sampler.probabilities(s_state)
        draw = sampler.sample_from(p, k_draw)
        if avail_on:
            # Same fold_in streams (101/102/103) as the simulation stack, off
            # the draw key; the draw's own key material is untouched.
            avail_mask, q_t, new_chain = stragglers.availability_step(
                faults,
                f_state.get("chain"),
                t,
                jax.random.fold_in(k_draw, 101),
                n,
            )
            avail_mask = sampler.shard_constrain(avail_mask)
            q_t = sampler.shard_constrain(q_t)
            draw = stragglers.available_draw(draw, avail_mask, q_t)
            if "chain" in f_state:
                f_state = {**f_state, "chain": sampler.shard_constrain(new_chain)}
        w_full = estimator.client_weights(draw, lam, sampler.procedure, sampler.budget)
        sel = select_cohort(
            draw.mask, w_full, spec.cohort, jax.random.fold_in(k_draw, 1)
        )
        overflow_dropped = sel.n_dropped
        deadline_dropped = jnp.zeros((), jnp.int32)
        if deadline_on:
            # Local training below still runs for every C slot (the server
            # already scheduled it); late slots are demoted to inert padding
            # so only the aggregation weights / feedback / loss see the drop,
            # with survivors rescaled by 1/surv for unbiasedness.
            lat_c = stragglers.latency_draw(
                faults, (sel.valid.shape[0],), jax.random.fold_in(k_draw, 102)
            )
            late_c = jnp.logical_and(sel.valid, lat_c > jnp.float32(faults.deadline))
            sel = mask_selection(sel, ~late_c, 1.0 / surv)
            deadline_dropped = jnp.sum(late_c.astype(jnp.int32))
        tokens, targets = gather_cohort(sel, k_data)
        if comp_on:
            new_params, norms, loss, new_resid = round_step(
                params, tokens, targets, sel.weights, resid=c_state.get("resid")
            )
            if ef_on:
                c_state = {"resid": new_resid}
        else:
            new_params, norms, loss = round_step(params, tokens, targets, sel.weights)
        if async_on:
            # round_step already applied x - server_lr * d; recover the
            # update u = server_lr * d, route it through the carried (B, D)
            # stale-delta ring, and apply only what arrived this round.
            u = jax.tree_util.tree_map(lambda a, b: a - b, params, new_params)
            new_buf, apply_vec, _ = stragglers.async_step(
                faults,
                f_state["buf"],
                stragglers.tree_to_vec(u),
                t,
                jax.random.fold_in(k_draw, 103),
                compression=comp,
            )
            f_state = {**f_state, "buf": new_buf}
            d_apply = stragglers.vec_to_tree(apply_vec, params)
            params = jax.tree_util.tree_map(lambda a, g: a - g, params, d_apply)
        else:
            params = new_params
        # Sampler feedback: (N,)-vector scatter of the (C,) cohort norms,
        # constrained back onto the sampler's (N,)-shard layout so the
        # scatter result never materializes replicated at scale.
        fb = sampler.shard_constrain(
            jnp.zeros((n,), jnp.float32).at[sel.ids].add(
                jnp.where(sel.valid, lam[sel.ids] * norms, 0.0)
            )
        )
        s_state = sampler.update(s_state, draw, fb)
        metrics = {
            "loss": loss,
            "cohort_size": jnp.sum(sel.valid.astype(jnp.int32)),
            "dropped": overflow_dropped,
        }
        if deadline_on:
            metrics["deadline_dropped"] = deadline_dropped
        out = (params, s_state)
        if fault_on:
            out = out + (f_state,)
        if ef_on:
            out = out + (c_state,)
        return out, metrics

    return body


def scan_body_for_lint(
    cfg: ArchConfig,
    spec: RoundSpec,
    sampler,
    dataset,
    *,
    mesh=None,
    constrain=None,
):
    """Lintable handle on the pod-scale scan body: ``(body, (carry, xs))``.

    ``carry``/``xs`` are ShapeDtypeStruct pytrees matching what
    ``build_fed_scan``/``build_fed_scan_segment`` scan the body with — the
    model parameters come from ``jax.eval_shape`` of ``transformer.
    init_params``, so no weights are materialized and the static checkers in
    ``repro.analysis.lint`` can trace the real round program for free."""
    from repro.core import stragglers

    body = _build_scan_body(cfg, spec, sampler, dataset, mesh, constrain)
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params = jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)
    carry = (params, sampler.abstract_state())
    xs = jax.eval_shape(lambda k: jnp.stack([k, k]), key)
    if spec.faults is not None:
        carry = carry + (
            stragglers.abstract_fault_state(
                spec.faults,
                dataset.n_clients,
                stragglers.flat_dim(params),
                spec.compression,
            ),
        )
        xs = (jax.ShapeDtypeStruct((), jnp.int32), key, key)
    if spec.compression is not None and spec.compression.error_feedback:
        carry = carry + (
            {
                "resid": jax.ShapeDtypeStruct(
                    (stragglers.flat_dim(params),), jnp.float32
                )
            },
        )
    return body, (carry, xs)


def build_fed_scan_segment(
    cfg: ArchConfig,
    spec: RoundSpec,
    sampler,
    dataset,
    *,
    mesh=None,
    constrain=None,
    donate: bool = True,
) -> tuple:
    """Segment-shaped ``build_fed_scan``: ``(segment_fn, make_state)``.

    The same per-round body as ``build_fed_scan``, cut for the host-driven
    segmented horizon (``repro.fed.state.run_segmented``) so
    ``repro.launch.train --compiled`` can publish a checkpoint every
    ``--ckpt-every`` rounds and survive preemption:

    * ``make_state(params, s_state, key, total_rounds)`` builds the canonical
      ``TrainState`` at round 0 — ``key`` is the launcher's chain key, from
      which each round's ``key, k_draw, k_data = split(key, 3)`` derives (the
      identical stream the host loop and the monolithic ``build_fed_scan``
      caller consume), and the ``loss``/``cohort_size``/``dropped`` metric
      buffers are zero-preallocated for the FULL horizon.  It is also the
      restore template for ``CheckpointManager.restore_or_init``.
    * ``segment_fn(state, n_rounds)`` comes from the shared
      ``fed.state.make_segment_fn`` machinery: it derives the next
      ``n_rounds`` key pairs in-trace, scans the round body, and stitches the
      stacked metrics into the buffers at offset ``state.round`` — bitwise
      identical to the monolithic scan under any segmentation
      (tests/test_segmented_scan.py).

    The launcher round step is stateless on the server side (``server_lr``
    applied directly), so ``TrainState.opt_state`` is ``()``.
    """
    from repro.core import stragglers

    body = _build_scan_body(cfg, spec, sampler, dataset, mesh, constrain)
    fault_on = spec.faults is not None
    ef_on = spec.compression is not None and bool(spec.compression.error_feedback)

    def derive_step(k, _):
        k, k_draw, k_data = jax.random.split(k, 3)
        return k, jnp.stack([k_draw, k_data])

    def fault_init(params):
        return stragglers.fault_state_init(
            spec.faults,
            dataset.n_clients,
            stragglers.flat_dim(params),
            spec.compression,
        )

    def comp_init(params):
        return {"resid": jnp.zeros((stragglers.flat_dim(params),), jnp.float32)}

    def make_state(params, s_state, key, total_rounds: int) -> TrainState:
        f_state = fault_init(params) if fault_on else ()
        c_state = comp_init(params) if ef_on else ()
        carry0 = (params, s_state) + ((f_state,) if fault_on else ())
        if ef_on:
            carry0 = carry0 + (c_state,)
        xs0 = (
            (jnp.zeros((), jnp.int32), key, key)
            if fault_on
            else jnp.stack([key, key])
        )
        return TrainState(
            params=params,
            opt_state=(),
            sampler=s_state,
            metrics=init_metric_buffers(body, carry0, xs0, total_rounds),
            round=jnp.zeros((), jnp.int32),
            key=key,
            faults=f_state,
            compression=c_state,
        )

    placement = None
    if getattr(sampler, "shard", None) is not None:
        # Shape-only template: the metrics dict's structure (and its lack of
        # any (N,)-axis buffer) is the same for every horizon length, so a
        # 1-round buffer set is enough to derive the placement pytree.
        key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
        params_s = jax.eval_shape(lambda k: transformer.init_params(cfg, k), key_s)
        f_state_s = jax.eval_shape(fault_init, params_s) if fault_on else ()
        c_state_s = jax.eval_shape(comp_init, params_s) if ef_on else ()
        carry_s = (params_s, sampler.abstract_state()) + (
            (f_state_s,) if fault_on else ()
        )
        if ef_on:
            carry_s = carry_s + (c_state_s,)
        xs_s = (
            (jax.ShapeDtypeStruct((), jnp.int32), key_s, key_s)
            if fault_on
            else jax.eval_shape(lambda k: jnp.stack([k, k]), key_s)
        )
        template = TrainState(
            params=params_s,
            opt_state=(),
            sampler=sampler.abstract_state(),
            metrics=init_metric_buffers(body, carry_s, xs_s, 1),
            round=jax.ShapeDtypeStruct((), jnp.int32),
            key=key_s,
            faults=f_state_s,
            compression=c_state_s,
        )
        placement = build_placement(template, sampler)

    segment = make_segment_fn(
        body, derive_step,
        with_opt_state=False, with_round_index=fault_on, with_faults=fault_on,
        with_compression=ef_on, donate=donate, placement=placement,
    )
    return segment, make_state
