"""Pod-scale federated round steps (the distributed Algorithm 1).

Two cohort execution modes (DESIGN.md section 3):

* client_parallel — cohort members vmapped across the batch ('data'/'pod')
  mesh axes; per-client diverged params live concurrently (C copies, each
  tensor-sharded over 'model').  Round latency ~= one client's local run.
* cohort_sequential — lax.scan over cohort members; each member's batch is
  itself data-parallel and params are FSDP-sharded over (batch x model)
  axes; only ONE diverged copy + the accumulator exist at a time, which is
  what lets llama3-405b / arctic-480b run true R-step local training.

Both produce:
  new_params  — x^{t+1} = x^t - eta_g * d^t with the unbiased ISP estimate
                d^t = sum_c w_c * (x^t - x_c^{t,R}),  w_c = m_c lambda_c / p~_c
  feedback    — pi_t(c) = ||delta_c||  (weights applied by the server, which
                knows lambda; the norm rides the aggregation pass)
  mean loss over the active (w != 0) cohort slots — padding is inert.

The round consumes a *static padded cohort* of size C with the inclusion
mask folded into w (w_c = 0 for padding) — ISP's stochastic |S^t| maps onto
fixed TPU shapes this way.  Selection/padding/weight semantics live in
``repro.fed.cohort`` (the shared contract with the compiled server loop and
the launcher); this module is the device-side consumer of that contract.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.fed.cohort import weighted_delta_sum
from repro.models import transformer
from repro.models.common import ArchConfig

__all__ = ["RoundSpec", "build_round_step"]


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    cohort: int  # padded cohort size C
    local_steps: int  # R
    local_lr: float = 0.02
    server_lr: float = 1.0


def _tree_sq_norm(delta):
    sq = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), delta
    )
    return jax.tree_util.tree_reduce(jnp.add, sq)


def _local_train(params, cfg: ArchConfig, batches, lr: float):
    """R local SGD steps on one client. batches: pytree with leading R axis.

    Returns (delta = x0 - xR, last-step loss)."""

    def step(p, batch):
        loss, grads = jax.value_and_grad(lambda q: transformer.loss_fn(q, cfg, batch))(p)
        p = jax.tree_util.tree_map(lambda w, g: w - lr * g.astype(w.dtype), p, grads)
        return p, loss

    final, losses = jax.lax.scan(step, params, batches)
    delta = jax.tree_util.tree_map(lambda a, b: (a - b).astype(a.dtype), params, final)
    return delta, losses[-1]


def build_round_step(cfg: ArchConfig, spec: RoundSpec, constrain=None) -> Callable:
    """Returns round_step(params, tokens, targets, weights[, aux_embeds]).

    tokens/targets: (C, R, B_local, S) int32 — each cohort member's R local
    batches.  aux_embeds (multimodal archs): (C, R, B_local, S_front, F).
    weights: (C,) f32 — m_c * lambda_c / p~_c (zero for cohort padding).
    constrain: optional fn(param-like pytree) -> pytree applying sharding
    constraints — REQUIRED at scale for cohort_sequential so the f32
    estimate accumulator stays FSDP-sharded instead of being replicated and
    all-reduced every cohort step (EXPERIMENTS.md section Perf, qwen3 iter 1).
    """
    mode = cfg.round_mode
    if constrain is None:
        constrain = lambda tree: tree

    def per_client(params, tok, tgt, aux):
        batches = (tok, tgt) if aux is None else (tok, tgt, aux)
        delta, loss = _local_train(params, cfg, batches, spec.local_lr)
        return delta, loss, jnp.sqrt(_tree_sq_norm(delta))

    def cohort_mean_loss(losses, weights):
        # Padding slots (w == 0) hold inert all-zero batches; their loss is
        # meaningless and must not pollute the round's reported loss.
        active = weights != 0.0
        return jnp.sum(jnp.where(active, losses, 0.0)) / jnp.maximum(
            jnp.sum(active.astype(jnp.float32)), 1.0
        )

    if mode == "client_parallel":

        def round_step(params, tokens, targets, weights, aux_embeds=None):
            def one(tok, tgt, aux):
                return per_client(params, tok, tgt, aux)

            if aux_embeds is None:
                deltas, losses, norms = jax.vmap(
                    lambda tok, tgt: one(tok, tgt, None)
                )(tokens, targets)
            else:
                deltas, losses, norms = jax.vmap(one)(tokens, targets, aux_embeds)
            d = weighted_delta_sum(deltas, weights)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - spec.server_lr * g.astype(p.dtype), params, d
            )
            return new_params, norms, cohort_mean_loss(losses, weights)

        return round_step

    if mode == "cohort_sequential":

        def round_step(params, tokens, targets, weights, aux_embeds=None):
            acc0 = constrain(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
            )

            def body(acc, inp):
                if aux_embeds is None:
                    tok, tgt, w = inp
                    aux = None
                else:
                    tok, tgt, w, aux = inp
                delta, loss, norm = per_client(params, tok, tgt, aux)
                delta = constrain(delta)
                acc = jax.tree_util.tree_map(
                    lambda a, dl: a + w * dl.astype(jnp.float32), acc, delta
                )
                return constrain(acc), (loss, norm)

            xs = (
                (tokens, targets, weights)
                if aux_embeds is None
                else (tokens, targets, weights, aux_embeds)
            )
            d, (losses, norms) = jax.lax.scan(body, acc0, xs)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - spec.server_lr * g.astype(p.dtype), params, d
            )
            return new_params, norms, cohort_mean_loss(losses, weights)

        return round_step

    raise ValueError(f"unknown round_mode {mode!r}")
