"""Trainable tasks for the paper-scale federated experiments.

Each task bundles: parameter init, a per-batch loss, and an accuracy metric.
The large-architecture zoo (src/repro/models) plugs into the same interface
through ``repro.fed.lm_task``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Task", "logistic_regression", "mlp_classifier", "tiny_lm"]


@dataclasses.dataclass(frozen=True)
class Task:
    name: str
    init: Callable  # key -> params
    loss: Callable  # (params, (x, y)) -> scalar
    accuracy: Callable  # (params, (x, y)) -> scalar


def _xent(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def logistic_regression(dim: int = 60, n_classes: int = 10) -> Task:
    """The paper's Section 6.1 model: f(x) = argmax(Wx + b)."""

    def init(key):
        kw, _ = jax.random.split(key)
        return {
            "w": jax.random.normal(kw, (dim, n_classes)) * 0.01,
            "b": jnp.zeros((n_classes,)),
        }

    def loss(params, batch):
        x, y = batch
        logits = x @ params["w"] + params["b"]
        return _xent(logits, y)

    def accuracy(params, batch):
        x, y = batch
        logits = x @ params["w"] + params["b"]
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    return Task("logreg", init, loss, accuracy)


def mlp_classifier(dim: int, n_classes: int, hidden: int = 128, depth: int = 2) -> Task:
    """Stand-in for the paper's FEMNIST CNN at CPU-simulation scale."""

    def init(key):
        keys = jax.random.split(key, depth + 1)
        sizes = [dim] + [hidden] * depth + [n_classes]
        return {
            f"l{i}": {
                "w": jax.random.normal(keys[i], (sizes[i], sizes[i + 1]))
                * jnp.sqrt(2.0 / sizes[i]),
                "b": jnp.zeros((sizes[i + 1],)),
            }
            for i in range(depth + 1)
        }

    def forward(params, x):
        h = x
        n_layers = len(params)
        for i in range(n_layers):
            h = h @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    def loss(params, batch):
        x, y = batch
        return _xent(forward(params, x), y)

    def accuracy(params, batch):
        x, y = batch
        return jnp.mean((jnp.argmax(forward(params, x), -1) == y).astype(jnp.float32))

    return Task("mlp", init, loss, accuracy)


def tiny_lm(vocab: int = 256, d_model: int = 64, n_layers: int = 2, n_heads: int = 4) -> Task:
    """Miniature decoder LM for the Section 6.3-style federated text task.

    Pure-jnp causal transformer (the full zoo lives in repro.models; this one
    keeps the paper-faithful experiment self-contained and CPU-fast).
    """

    def init(key):
        ks = jax.random.split(key, 2 + 4 * n_layers)
        d_ff = 4 * d_model
        params = {
            "emb": jax.random.normal(ks[0], (vocab, d_model)) * 0.02,
        }
        for i in range(n_layers):
            params[f"blk{i}"] = {
                "qkv": jax.random.normal(ks[2 + 4 * i], (d_model, 3 * d_model)) * 0.02,
                "proj": jax.random.normal(ks[3 + 4 * i], (d_model, d_model)) * 0.02,
                "up": jax.random.normal(ks[4 + 4 * i], (d_model, d_ff)) * 0.02,
                "down": jax.random.normal(ks[5 + 4 * i], (d_ff, d_model)) * 0.02,
            }
        return params

    head_dim = d_model // n_heads

    def forward(params, tokens):
        b, s = tokens.shape
        h = params["emb"][tokens]
        mask = jnp.tril(jnp.ones((s, s), bool))
        for i in range(n_layers):
            blk = params[f"blk{i}"]
            x = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
            qkv = x @ blk["qkv"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(b, s, n_heads, head_dim)
            k = k.reshape(b, s, n_heads, head_dim)
            v = v.reshape(b, s, n_heads, head_dim)
            att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(head_dim)
            att = jnp.where(mask[None, None], att, -1e9)
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d_model)
            h = h + o @ blk["proj"]
            x = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
            h = h + jax.nn.gelu(x @ blk["up"]) @ blk["down"]
        x = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
        return x @ params["emb"].T

    def loss(params, batch):
        tokens, targets = batch
        logits = forward(params, tokens)
        return _xent(logits, targets)

    def accuracy(params, batch):
        tokens, targets = batch
        logits = forward(params, tokens)
        return jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))

    return Task("tiny_lm", init, loss, accuracy)
