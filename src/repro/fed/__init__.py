from repro.fed.client import local_update, update_norm
from repro.fed.cohort import CohortSelection, select_cohort
from repro.fed.round import RoundSpec, build_fed_scan, build_round_step
from repro.fed.server import FedConfig, History, run_federated
from repro.fed.tasks import Task, logistic_regression, mlp_classifier, tiny_lm

__all__ = [
    "local_update",
    "update_norm",
    "CohortSelection",
    "select_cohort",
    "RoundSpec",
    "build_fed_scan",
    "build_round_step",
    "FedConfig",
    "History",
    "run_federated",
    "Task",
    "logistic_regression",
    "mlp_classifier",
    "tiny_lm",
]
