from repro.fed.client import local_update, update_norm
from repro.fed.cohort import CohortSelection, select_cohort
from repro.fed.round import (
    RoundSpec,
    build_fed_scan,
    build_fed_scan_segment,
    build_round_step,
    scan_body_for_lint,
)
from repro.fed.server import (
    FedConfig,
    History,
    build_segment_runner,
    round_body_for_lint,
    run_federated,
)
from repro.fed.state import TrainState, run_segmented
from repro.fed.tasks import Task, logistic_regression, mlp_classifier, tiny_lm

__all__ = [
    "local_update",
    "update_norm",
    "CohortSelection",
    "select_cohort",
    "RoundSpec",
    "build_fed_scan",
    "build_fed_scan_segment",
    "build_round_step",
    "scan_body_for_lint",
    "FedConfig",
    "History",
    "build_segment_runner",
    "round_body_for_lint",
    "run_federated",
    "TrainState",
    "run_segmented",
    "Task",
    "logistic_regression",
    "mlp_classifier",
    "tiny_lm",
]
