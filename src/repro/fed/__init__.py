from repro.fed.client import local_update, update_norm
from repro.fed.server import FedConfig, History, run_federated
from repro.fed.tasks import Task, logistic_regression, mlp_classifier, tiny_lm

__all__ = [
    "local_update",
    "update_norm",
    "FedConfig",
    "History",
    "run_federated",
    "Task",
    "logistic_regression",
    "mlp_classifier",
    "tiny_lm",
]
