"""Federated server loop (Algorithm 1) — simulation-scale driver.

Two execution modes:

* ``oracle_metrics=True``: every round computes *all* clients' local updates
  (vmapped) so the paper's diagnostics — dynamic regret (eq. 8), estimator
  variance (eq. 2), sampling quality — are exact.  This is how the paper's
  figures are generated (the oracle is a property of the simulation, not of
  the deployed server).
* ``oracle_metrics=False``: only the sampled cohort computes (padded to a
  static buffer), which is the deployable configuration; metrics are limited
  to what a real server can observe.

The pod-scale distributed round lives in ``repro.fed.round`` and
``repro.launch`` — this module is the algorithmic reference loop and is what
validates the paper's claims on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, samplers
from repro.core.regret import RegretTracker
from repro.fed import client as fed_client
from repro.fed.tasks import Task
from repro.optim.fedopt import FedAvgServer, ServerOptimizer

__all__ = ["FedConfig", "History", "run_federated"]


@dataclasses.dataclass(frozen=True)
class FedConfig:
    rounds: int = 100
    budget: int = 10
    local_steps: int = 1
    batch_size: int = 64
    local_lr: float = 0.02
    server_opt: ServerOptimizer = FedAvgServer(lr=1.0)
    seed: int = 0
    eval_every: int = 5
    eval_batches: int = 4
    oracle_metrics: bool = True


@dataclasses.dataclass
class History:
    rounds: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    test_accuracy: list = dataclasses.field(default_factory=list)
    estimator_sq_error: list = dataclasses.field(default_factory=list)
    cohort_size: list = dataclasses.field(default_factory=list)
    regret: RegretTracker | None = None
    wall_time_s: float = 0.0

    def summary(self) -> dict:
        out = {
            "final_loss": self.train_loss[-1] if self.train_loss else None,
            "final_acc": self.test_accuracy[-1] if self.test_accuracy else None,
            "mean_sq_error": float(np.mean(self.estimator_sq_error))
            if self.estimator_sq_error
            else None,
            "mean_cohort": float(np.mean(self.cohort_size)) if self.cohort_size else None,
            "wall_time_s": self.wall_time_s,
        }
        if self.regret is not None and self.regret.costs:
            out["final_dynamic_regret_per_round"] = float(
                self.regret.dynamic_regret()[-1] / len(self.regret.costs)
            )
        return out


def _all_client_round(task: Task, dataset, local_steps: int, batch_size: int, local_lr: float):
    """Build the jitted all-clients local-update function (oracle mode)."""

    lam = dataset.lam

    @jax.jit
    def round_fn(params, key):
        n = dataset.n_clients
        keys = jax.random.split(key, n * local_steps).reshape(n, local_steps, 2)

        def one_client(i, ks):
            def get_batch(k):
                return dataset.client_batch(i, k, batch_size)

            batches = jax.vmap(get_batch)(ks)
            delta, loss = fed_client.local_update(params, task.loss, batches, local_lr)
            return delta, loss, fed_client.update_norm(delta)

        deltas, losses, norms = jax.vmap(one_client)(jnp.arange(dataset.n_clients), keys)
        feedback = lam * norms  # pi_t(i) = lambda_i ||g_i||
        return deltas, losses, feedback

    return round_fn


def run_federated(
    task: Task,
    dataset,
    sampler: samplers.Sampler,
    cfg: FedConfig,
    eval_data: tuple | None = None,
) -> History:
    t0 = time.time()
    key = jax.random.PRNGKey(cfg.seed)
    key, init_key = jax.random.split(key)
    params = task.init(init_key)
    opt_state = cfg.server_opt.init(params)
    s_state = sampler.init()
    lam = dataset.lam

    hist = History(regret=RegretTracker(budget=cfg.budget))
    round_fn = _all_client_round(task, dataset, cfg.local_steps, cfg.batch_size, cfg.local_lr)

    apply_fn = jax.jit(
        lambda p, d, o: cfg.server_opt.apply(p, d, o), donate_argnums=(0,)
    )

    @jax.jit
    def estimate_fn(deltas, weights, feedback_masked):
        d = estimator.aggregate_stacked(deltas, weights)
        return d

    @jax.jit
    def error_fn(deltas, weights):
        d = estimator.aggregate_stacked(deltas, weights)
        tgt = estimator.full_aggregate_stacked(deltas, lam)
        return estimator.empirical_sq_error(d, tgt)

    eval_fn = jax.jit(lambda p, b: task.accuracy(p, b))

    for t in range(cfg.rounds):
        key, k_data, k_sample = jax.random.split(key, 3)
        deltas, losses, feedback_full = round_fn(params, k_data)

        p_marg = sampler.probabilities(s_state)
        draw = sampler.sample(s_state, k_sample)
        weights = estimator.client_weights(draw, lam, sampler.procedure, sampler.budget)
        d_est = estimate_fn(deltas, weights, feedback_full * draw.mask)
        params, opt_state = apply_fn(params, d_est, opt_state)

        # The server only observes sampled feedback (Theorem 5.2's partial
        # feedback): mask before the sampler update.
        s_state = sampler.update(s_state, draw, feedback_full * draw.mask)

        # ---- diagnostics (oracle side) ----
        if cfg.oracle_metrics:
            if sampler.procedure == "isp":
                p_eff = draw.marginals
            else:
                p_eff = sampler.budget * draw.draw_probs
            hist.regret.record(feedback_full, p_eff)
            hist.estimator_sq_error.append(float(error_fn(deltas, weights)))
        hist.cohort_size.append(int(draw.size))
        hist.rounds.append(t)
        hist.train_loss.append(float(jnp.sum(lam * losses)))

        if eval_data is not None and (t % cfg.eval_every == 0 or t == cfg.rounds - 1):
            hist.test_accuracy.append(float(eval_fn(params, eval_data)))

    hist.wall_time_s = time.time() - t0
    return hist
