"""Federated server loop (Algorithm 1) — simulation-scale driver.

The canonical way to describe and launch a run is the declarative
``repro.api.ExperimentSpec`` (``repro.api.run(spec)`` dispatches here for
simulation tasks and builds the exact ``(task, dataset, sampler, FedConfig)``
tuple ``run_federated`` takes — bitwise-identical by construction, pinned by
tests/test_api_spec.py).  ``run_federated`` remains the stable programmatic
entry point underneath.

Two execution modes share ONE round body (``_build_round_body``):

* ``compiled=True`` (default): the training run — all-clients local update,
  sampler probabilities/sample/update, unbiased aggregation, server optimizer
  apply, and metric accumulation (loss, estimator squared error, cohort size,
  per-round online costs ``l_t(p^t)`` / ``min_p l_t(p)``) — executes as a
  host-driven loop over jitted ``lax.scan`` *segments* of
  ``FedConfig.ckpt_every`` rounds (``ckpt_every=0``: one segment, the
  monolithic scan) with the carry round-tripping through the canonical
  ``repro.fed.state.TrainState`` pytree.  Segmentation is a pure reshaping of
  the horizon — results are bitwise identical for ANY ``ckpt_every``
  (tests/test_segmented_scan.py) — but each boundary is an escape hatch where
  a ``repro.checkpoint.CheckpointManager`` can publish the full state, so
  long horizons survive preemption with the sampler's learned probabilities
  intact.  Metrics live in on-device (T,)-preallocated buffers stitched
  segment by segment and the ``History`` is materialized once at the end:
  zero host round-trips per round instead of the reference loop's 5+.
* ``compiled=False``: the same body is jitted and dispatched one round at a
  time from Python with per-round host syncs — the debuggable reference loop
  (prints, breakpoints, and per-round inspection work).

Because both modes run the identical traced computation, they produce
bit-identical parameters and metrics (see tests/test_scan_server.py).

Two metric fidelities:

* ``oracle_metrics=True``: every round computes *all* clients' local updates
  (vmapped) so the paper's diagnostics — dynamic regret (eq. 8), estimator
  variance (eq. 2), sampling quality — are exact.  This is how the paper's
  figures are generated (the oracle is a property of the simulation, not of
  the deployed server).
* ``oracle_metrics=False`` (deployable mode): the round trains ONLY a static
  C-slot cohort (``FedConfig.cohort``) selected from the ISP draw inside the
  traced body via ``fed.cohort.select_cohort`` — local-update compute is
  O(C) per round instead of O(N), which is the whole point of expected-K
  client sampling.  Overflow (``|S| > C``) drops to a uniform size-C subset
  with weights rescaled by ``|S|/C`` so the estimate stays unbiased.
  Aggregation is C-width by default (``estimator.aggregate_and_error_cohort``
  — O(C*D), no (N, D) buffer exists anywhere in the round body), which
  matches the oracle computation to float tolerance; setting
  ``FedConfig.exact_oracle_equiv=True`` restores the (N, D) scatter path,
  bit-identical to the full-mask computation whenever ``|S| <= C``
  (tests/test_scan_server.py; fed/cohort.py "Aggregation width").
  Diagnostics requiring full feedback are skipped; ``train_loss`` is the
  importance-weighted cohort estimate of the full weighted loss (unbiased,
  but noisier than the oracle's exact value), ``cohort_size`` counts the
  clients actually contacted (post-drop), and ``History.cohort_dropped``
  records the per-round overflow drops.

The pod-scale distributed round lives in ``repro.fed.round`` and
``repro.launch`` — this module is the algorithmic reference loop and is what
validates the paper's claims on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator, regret, samplers, stragglers
from repro.core.regret import RegretTracker
from repro.fed import client as fed_client
from repro.fed import cohort as fed_cohort
from repro.fed.state import (
    TrainState,
    build_placement,
    init_metric_buffers,
    make_segment_fn,
    run_segmented,
)
from repro.fed.tasks import Task
from repro.optim.fedopt import FedAvgServer, ServerOptimizer

__all__ = [
    "FedConfig",
    "History",
    "build_segment_runner",
    "round_body_for_lint",
    "run_federated",
]


@dataclasses.dataclass(frozen=True)
class FedConfig:
    rounds: int = 100
    budget: int = 10
    local_steps: int = 1
    batch_size: int = 64
    local_lr: float = 0.02
    server_opt: ServerOptimizer = FedAvgServer(lr=1.0)
    seed: int = 0
    eval_every: int = 5
    eval_batches: int = 4
    oracle_metrics: bool = True
    compiled: bool = True  # False: per-round Python dispatch (debug/reference)
    # Deployable-mode (oracle_metrics=False) static cohort buffer size C;
    # None -> min(2 * budget, n_clients).  Ignored in oracle mode.
    cohort: int | None = None
    # Deployable-mode aggregation width.  False (default): aggregate directly
    # over the (C, ...) cohort deltas — O(C*D) per round, no (N, D) buffer,
    # allclose to the oracle path (the reduction order differs).  True:
    # scatter the cohort back to (N, ...) buffers and reuse the oracle
    # contraction — bitwise equal to the oracle path when |S| <= C, at O(N*D)
    # memory cost.  Ignored in oracle mode.
    exact_oracle_equiv: bool = False
    # Oracle-mode (T, N) per-round score history buffer for the regret
    # diagnostics.  Pure diagnostic weight at large T*N; turn off to drop it
    # from the on-device metrics (regret costs are still tracked).
    track_scores: bool = True
    # Explicit size guard for that (T, N) buffer: build_segment_runner raises
    # (instead of silently OOMing the device at large N) when the buffer
    # would exceed this many bytes and host offload is off.
    score_history_bytes_limit: int = 1 << 30
    # Chunked host offload for the score history: the device buffer shrinks
    # to (ckpt_every, N) — a ring the segment stitch wraps into — and every
    # segment boundary drains it to host memory, where the full (T, N)
    # history is assembled for the regret diagnostics.  Requires the
    # compiled path with ckpt_every > 0.
    score_history_host_offload: bool = False
    # Compiled-path segment length: the scan runs in jitted segments of this
    # many rounds so a CheckpointManager can publish the full TrainState at
    # every boundary.  0 = whole horizon as one segment (the monolithic
    # scan).  Bitwise-neutral: any value yields identical results.
    ckpt_every: int = 0
    # Deployment-realism fault layer: a ``repro.api.FaultSpec`` (duck-typed —
    # anything with its fields works) or None.  None (default) builds the
    # exact pre-fault round body, so existing runs stay bitwise.  When set,
    # the round body threads the availability process / deadline-straggler
    # dropout / buffered-async aggregation from ``repro.core.stragglers``
    # through the traced round, with the fault state carried in
    # ``TrainState.faults``.
    faults: object | None = None
    # Delta-width compression layer: a ``repro.api.CompressionSpec`` (duck-
    # typed) or None.  None (default) builds the exact pre-compression round
    # body.  When set, client deltas are quantized to int8/fp8 with
    # per-(slot, block) fp32 scales inside the traced round and aggregated by
    # the fused dequantize-in-VMEM kernel; with ``error_feedback`` the server
    # carries a (D,) f32 residual in ``TrainState.compression``.
    compression: object | None = None

    def cohort_slots(self, n_clients: int) -> int:
        c = 2 * self.budget if self.cohort is None else int(self.cohort)
        return max(1, min(c, n_clients))


@dataclasses.dataclass
class History:
    rounds: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    test_accuracy: list = dataclasses.field(default_factory=list)
    estimator_sq_error: list = dataclasses.field(default_factory=list)
    cohort_size: list = dataclasses.field(default_factory=list)
    cohort_dropped: list = dataclasses.field(default_factory=list)  # deployable
    # Per-round count of clients that missed the FaultSpec deadline (faulted
    # runs with deadline set; empty otherwise).
    deadline_dropped: list = dataclasses.field(default_factory=list)
    regret: RegretTracker | None = None
    wall_time_s: float = 0.0
    final_params: object = None  # trained parameter pytree (trajectory probe)

    def summary(self) -> dict:
        out = {
            "final_loss": self.train_loss[-1] if self.train_loss else None,
            "final_acc": self.test_accuracy[-1] if self.test_accuracy else None,
            "mean_sq_error": float(np.mean(self.estimator_sq_error))
            if self.estimator_sq_error
            else None,
            "mean_cohort": float(np.mean(self.cohort_size)) if self.cohort_size else None,
            "wall_time_s": self.wall_time_s,
        }
        if self.regret is not None and self.regret.costs:
            out["final_dynamic_regret_per_round"] = float(
                self.regret.dynamic_regret()[-1] / len(self.regret.costs)
            )
        return out


def _build_client_step(task: Task, dataset, cfg: FedConfig):
    """One client's local update: (params, client id, (R, 2) batch keys) ->
    (delta, loss, update norm).  Shared by the oracle and deployable paths so
    their per-client numerics cannot drift apart — cross-mode bit-identity
    (tests/test_scan_server.py) depends on this being a single definition."""

    def one_client(params, i, ks):
        def get_batch(k):
            return dataset.client_batch(i, k, cfg.batch_size)

        batches = jax.vmap(get_batch)(ks)
        delta, loss = fed_client.local_update(params, task.loss, batches, cfg.local_lr)
        return delta, loss, fed_client.update_norm(delta)

    return one_client


def _split_batch_keys(key, n: int, local_steps: int):
    """(N, R, 2) per-client batch keys — the one key stream both paths index."""
    return jax.random.split(key, n * local_steps).reshape(n, local_steps, 2)


def _build_all_clients(task: Task, dataset, cfg: FedConfig):
    """All-clients local-update step (oracle mode): vmapped over clients."""

    lam = dataset.lam
    n = dataset.n_clients
    one_client = _build_client_step(task, dataset, cfg)

    def all_clients(params, key):
        keys = _split_batch_keys(key, n, cfg.local_steps)
        deltas, losses, norms = jax.vmap(
            lambda i, ks: one_client(params, i, ks)
        )(jnp.arange(n), keys)
        feedback = lam * norms  # pi_t(i) = lambda_i ||g_i||
        return deltas, losses, feedback

    return all_clients


def _build_cohort_clients(task: Task, dataset, cfg: FedConfig):
    """Cohort-only local-update step (deployable mode): vmapped over the C
    selected slots.  Batch keys are split for all N clients exactly as in
    ``_build_all_clients`` and then gathered by client id, so a cohort
    client's batches — and therefore its delta/loss/norm — are bit-identical
    to what the oracle path computes for that client (key material is O(N)
    but cheap; the O(N * local-train) compute is what this path removes)."""

    n = dataset.n_clients
    one_client = _build_client_step(task, dataset, cfg)

    def cohort_clients(params, key, cohort_ids):
        keys = _split_batch_keys(key, n, cfg.local_steps)
        return jax.vmap(lambda i, ks: one_client(params, i, ks))(
            cohort_ids, keys[cohort_ids]
        )

    return cohort_clients


def _build_round_body(task: Task, dataset, sampler: samplers.Sampler, cfg: FedConfig, eval_data):
    """One federated round as a scan body: (carry, (t, k_data, k_sample)) ->
    (carry, per-round metrics dict).  Pure and shape-static, so it runs
    identically under ``lax.scan`` and under per-round ``jit`` dispatch.

    Oracle mode trains all N clients; deployable mode (oracle_metrics=False)
    trains only the C-slot cohort selected from the draw and aggregates at
    cohort width — O(C*D) with no (N, D) buffer — unless
    ``cfg.exact_oracle_equiv`` asks for the legacy N-width scatter, which
    reuses the oracle contraction and is bit-identical to it when
    ``|S| <= C`` (module docstring; fed/cohort.py "Aggregation width").

    ``cfg.faults`` (a ``repro.api.FaultSpec``) switches on the deployment-
    realism layer at BUILD time — carry grows a trailing fault-state element
    and the body threads ``core.stragglers``: the availability process
    intersects the draw (composed ``q * p`` correction, so the estimator
    stays unbiased), deadline stragglers are masked out after local training
    with survivor weights rescaled by ``1 / P(latency <= deadline)``, and
    buffered-async mode routes the round's aggregate through a carried
    (B, D) stale-delta ring instead of applying it immediately.  With
    ``faults=None`` the built body is the exact pre-fault program.

    ``cfg.compression`` (a ``repro.api.CompressionSpec``) likewise switches
    at BUILD time: the stacked client deltas are quantized inside the round
    (``estimator.aggregate_compressed``), sampler feedback norms come from
    the dequantized values, and with error feedback the carry grows a
    trailing ``{"resid": (D,) f32}`` element — the applied update is
    ``d_hat + resid`` and the residual absorbs the fresh quantization error
    ``d_true - d_hat`` so errors telescope across rounds.  With
    ``compression=None`` the built body is the exact pre-compression
    program."""

    lam = dataset.lam
    n = dataset.n_clients
    if cfg.oracle_metrics:
        all_clients = _build_all_clients(task, dataset, cfg)
    else:
        c_slots = cfg.cohort_slots(n)
        cohort_clients = _build_cohort_clients(task, dataset, cfg)

    faults = cfg.faults
    fault_on = faults is not None
    avail_on = fault_on and faults.availability is not None
    deadline_on = fault_on and faults.deadline is not None
    async_on = fault_on and int(faults.async_buffer) > 0
    # Static build-time survival probability: the unbiasedness rescale for
    # deadline survivors (raises if the deadline is unsatisfiable).
    surv = stragglers.deadline_survival(faults) if deadline_on else 1.0

    comp = cfg.compression
    comp_on = comp is not None
    ef_on = comp_on and bool(comp.error_feedback)
    if comp_on and not cfg.oracle_metrics and cfg.exact_oracle_equiv:
        raise ValueError(
            "compression is incompatible with exact_oracle_equiv: the N-width "
            "scatter path exists to reproduce the oracle contraction bitwise, "
            "which quantization cannot; use the cohort-width aggregation "
            "(exact_oracle_equiv=False)"
        )

    def body(carry, xs):
        c_state = {}
        if ef_on:
            carry, c_state = carry[:-1], carry[-1]
        if fault_on:
            params, opt_state, s_state, f_state = carry
        else:
            params, opt_state, s_state = carry
            f_state = {}
        t, k_data, k_sample = xs

        # Solve p~ once; reuse it for the draw AND the regret diagnostics
        # (the seed loop solved twice and diagnosed off draw.marginals).
        p_marg = sampler.probabilities(s_state)
        draw = sampler.sample_from(p_marg, k_sample)
        if avail_on:
            # Availability intersects the draw; composing q into the draw's
            # probabilities makes the plain client_weights call below the
            # availability-corrected (1/(q p)) estimator.  Distinct fold_in
            # streams (101/102/103) keep the sampler's own key untouched.
            avail_mask, q_t, new_chain = stragglers.availability_step(
                faults,
                f_state.get("chain"),
                t,
                jax.random.fold_in(k_sample, 101),
                n,
            )
            avail_mask = sampler.shard_constrain(avail_mask)
            q_t = sampler.shard_constrain(q_t)
            draw = stragglers.available_draw(draw, avail_mask, q_t)
            if "chain" in f_state:
                f_state = {**f_state, "chain": sampler.shard_constrain(new_chain)}
        weights = estimator.client_weights(draw, lam, sampler.procedure, sampler.budget)

        deadline_dropped = jnp.zeros((), jnp.int32)
        if cfg.oracle_metrics:
            deltas, losses, feedback_full = all_clients(params, k_data)
            feedback_full = sampler.shard_constrain(feedback_full)
            active = draw.mask
            if deadline_on:
                # Per-client latency; clients past the deadline report
                # nothing this round.  Survivor weights / surv keeps the
                # estimate unbiased (E[1{survive}] = surv, independent of
                # the draw).
                lat = stragglers.latency_draw(
                    faults, (n,), jax.random.fold_in(k_sample, 102)
                )
                late = jnp.logical_and(draw.mask, lat > jnp.float32(faults.deadline))
                active = jnp.logical_and(draw.mask, ~late)
                weights = jnp.where(late, 0.0, weights * jnp.float32(1.0 / surv))
                deadline_dropped = jnp.sum(late.astype(jnp.int32))
            feedback = feedback_full * active
            train_loss = jnp.sum(lam * losses)
            cohort_size = (
                jnp.sum(active.astype(jnp.int32)) if deadline_on else draw.size
            )
            if comp_on:
                # Compressed width: quantize the (N, ...) stacked deltas and
                # aggregate via the fused dequant kernel; the sampler's
                # feedback norms are recomputed from the dequantized values
                # (the regret signal is what the estimator actually saw), and
                # with error feedback the applied estimate is d_hat + resid.
                d_est, sq_err, norms_dq, new_resid = estimator.aggregate_compressed(
                    deltas, weights, lam, comp, c_state.get("resid")
                )
                feedback_full = sampler.shard_constrain(lam * norms_dq)
                feedback = feedback_full * active
                if ef_on:
                    c_state = {"resid": new_resid}
            else:
                # sq_err shares the one pass over the stacked (N, ...) deltas.
                d_est, sq_err = estimator.aggregate_and_error(deltas, weights, lam)
        else:
            # Deployable: select C slots from the draw (fold_in keeps the
            # draw's key stream untouched) and train only those clients.
            sel = fed_cohort.select_cohort(
                draw.mask, weights, c_slots, jax.random.fold_in(k_sample, 1)
            )
            overflow_dropped = sel.n_dropped
            deltas_c, losses_c, norms_c = cohort_clients(params, k_data, sel.ids)
            if deadline_on:
                # Deadline dropout AFTER local training is scheduled: the C
                # slots' compute already ran; late slots are demoted to inert
                # padding (weight/validity/feedback zeroed) and survivors are
                # rescaled by 1/surv — the O(C*D) aggregation below is
                # untouched (fed/cohort.py mask_selection).
                lat_c = stragglers.latency_draw(
                    faults, (c_slots,), jax.random.fold_in(k_sample, 102)
                )
                late_c = jnp.logical_and(
                    sel.valid, lat_c > jnp.float32(faults.deadline)
                )
                sel = fed_cohort.mask_selection(sel, ~late_c, 1.0 / surv)
                deadline_dropped = jnp.sum(late_c.astype(jnp.int32))
            # Sampler feedback is an (N,)-vector scatter of a (C,) vector —
            # the sampler state is legitimately N-sized; only the (N, D)
            # delta pytree scatter is the scale problem.  (Compressed rounds
            # scatter the dequantized norms instead, below.)
            if not comp_on:
                feedback = sampler.shard_constrain(
                    fed_cohort.scatter_cohort(
                        jnp.where(sel.valid, lam[sel.ids] * norms_c, 0.0), sel, n
                    )
                )
            # Unbiased cohort estimate of the full weighted loss sum_i lam_i l_i.
            train_loss = jnp.sum(jnp.where(sel.valid, sel.weights * losses_c, 0.0))
            # The clients actually contacted (post-overflow-drop), not |S|.
            cohort_size = jnp.sum(sel.valid.astype(jnp.int32))
            if cfg.exact_oracle_equiv:
                # Scatter back to (N, ...) buffers and reuse the oracle
                # contraction: bitwise equal to the oracle path when |S| <= C
                # (inserted zero terms cannot change the partial sums), at
                # O(N*D) memory cost.
                deltas = fed_cohort.scatter_cohort(deltas_c, sel, n)
                agg_weights = fed_cohort.scatter_cohort(sel.weights, sel, n)
                d_est, sq_err = estimator.aggregate_and_error(deltas, agg_weights, lam)
            elif comp_on:
                # Compressed cohort width: the (C, D) stacked buffer lives at
                # quantized width in HBM and is widened per VMEM tile inside
                # the fused dequant-aggregate kernel.  Feedback norms come
                # from the same pass (dequantized values); error feedback
                # applies/updates the carried residual.
                lam_c = jnp.where(sel.valid, lam[sel.ids], 0.0)
                d_est, sq_err, norms_dq, new_resid = estimator.aggregate_compressed(
                    deltas_c, sel.weights, lam_c, comp, c_state.get("resid")
                )
                feedback = sampler.shard_constrain(
                    fed_cohort.scatter_cohort(
                        jnp.where(sel.valid, lam[sel.ids] * norms_dq, 0.0), sel, n
                    )
                )
                if ef_on:
                    c_state = {"resid": new_resid}
            else:
                # Cohort-width aggregation: O(C*D), no (N, D) buffer exists
                # anywhere in the round (tests assert this on the jaxpr).
                # Same value as the scatter path in exact arithmetic; allclose
                # on hardware (fed/cohort.py "Aggregation width").
                lam_c = jnp.where(sel.valid, lam[sel.ids], 0.0)
                d_est, sq_err = estimator.aggregate_and_error_cohort(
                    deltas_c, sel.weights, lam_c
                )
        # sq_err is recorded only in oracle mode; the deployable branches'
        # error row is dead code and fused away.
        if async_on:
            # Buffered-async: the round's aggregate enters the carried (B, D)
            # stale-delta ring; the server applies only the staleness-
            # discounted deltas whose arrival round has come (possibly none).
            u_vec = stragglers.tree_to_vec(d_est)
            new_buf, apply_vec, _ = stragglers.async_step(
                faults,
                f_state["buf"],
                u_vec,
                t,
                jax.random.fold_in(k_sample, 103),
                compression=comp,
            )
            f_state = {**f_state, "buf": new_buf}
            d_apply = stragglers.vec_to_tree(apply_vec, d_est)
            params, opt_state = cfg.server_opt.apply(params, d_apply, opt_state)
        else:
            params, opt_state = cfg.server_opt.apply(params, d_est, opt_state)

        # The server only observes sampled feedback (Theorem 5.2's partial
        # feedback): masked to the cohort it actually contacted.
        s_state = sampler.update(s_state, draw, feedback)

        metrics = {
            "train_loss": train_loss,
            "cohort_size": cohort_size,
        }
        if deadline_on:
            metrics["deadline_dropped"] = deadline_dropped
        if not cfg.oracle_metrics:
            metrics["dropped"] = overflow_dropped
        if cfg.oracle_metrics:
            if sampler.procedure == "isp":
                p_eff = p_marg
            else:
                # K x per-draw distribution approximates the inclusion
                # marginal; clip to (0, 1] so degenerate draws (K q_i > 1)
                # cannot corrupt the regret/quality-gap diagnostics.
                p_eff = jnp.clip(sampler.budget * draw.draw_probs, 1e-30, 1.0)
            cost, opt_cost = regret.round_costs(feedback_full, p_eff, sampler.budget)
            metrics.update(sq_error=sq_err, cost=cost, opt_cost=opt_cost)
            if cfg.track_scores:
                # (T, N) stacked across the scan — pure diagnostic weight at
                # large T*N; opt out via FedConfig.track_scores=False.
                metrics["scores"] = feedback_full
        if eval_data is not None:
            do_eval = (t % cfg.eval_every == 0) | (t == cfg.rounds - 1)
            metrics["accuracy"] = jax.lax.cond(
                do_eval,
                lambda p: task.accuracy(p, eval_data).astype(jnp.float32),
                lambda p: jnp.full((), jnp.nan, jnp.float32),
                params,
            )
        out = (params, opt_state, s_state)
        if fault_on:
            out = out + (f_state,)
        if ef_on:
            out = out + (c_state,)
        return out, metrics

    return body


def round_body_for_lint(
    task: Task,
    dataset,
    sampler: samplers.Sampler,
    cfg: FedConfig,
    eval_data: tuple | None = None,
):
    """Lintable handle on the built round body: ``(body, (carry, xs))``.

    ``carry``/``xs`` are ShapeDtypeStruct pytrees shaped exactly as the
    compiled paths trace the body (``build_segment_runner``'s scan and the
    reference loop's per-round jit) — no arrays are materialized, so the
    static checkers in ``repro.analysis.lint`` can ``jax.make_jaxpr(body)``
    the real program without touching data or devices."""
    body = _build_round_body(task, dataset, sampler, cfg, eval_data)
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params = jax.eval_shape(task.init, key)
    opt_state = jax.eval_shape(cfg.server_opt.init, params)
    s_state = sampler.abstract_state()
    carry = (params, opt_state, s_state)
    if cfg.faults is not None:
        carry = carry + (
            stragglers.abstract_fault_state(
                cfg.faults,
                dataset.n_clients,
                stragglers.flat_dim(params),
                cfg.compression,
            ),
        )
    if cfg.compression is not None and cfg.compression.error_feedback:
        carry = carry + (
            {
                "resid": jax.ShapeDtypeStruct(
                    (stragglers.flat_dim(params),), jnp.float32
                )
            },
        )
    xs = (jax.ShapeDtypeStruct((), jnp.int32), key, key)
    return body, (carry, xs)


def _materialize_history(metrics: dict, cfg: FedConfig, has_eval: bool) -> History:
    """One host transfer at the end of the run: stacked device buffers ->
    the History lists the analysis/plotting code expects."""
    hist = History(regret=RegretTracker(budget=cfg.budget))
    hist.rounds = list(range(cfg.rounds))
    hist.train_loss = [float(x) for x in np.asarray(metrics["train_loss"])]
    hist.cohort_size = [int(x) for x in np.asarray(metrics["cohort_size"])]
    if "dropped" in metrics:
        hist.cohort_dropped = [int(x) for x in np.asarray(metrics["dropped"])]
    if "deadline_dropped" in metrics:
        hist.deadline_dropped = [
            int(x) for x in np.asarray(metrics["deadline_dropped"])
        ]
    if cfg.oracle_metrics:
        hist.estimator_sq_error = [float(x) for x in np.asarray(metrics["sq_error"])]
        hist.regret = RegretTracker.from_arrays(
            cfg.budget, metrics["cost"], metrics["opt_cost"], metrics.get("scores")
        )
    if has_eval:
        acc = np.asarray(metrics["accuracy"])
        hist.test_accuracy = [float(a) for a in acc[~np.isnan(acc)]]
    return hist


def _score_history_plan(cfg: FedConfig, n_clients: int):
    """Size-guard the oracle (T, N) score-history buffer and pick its device
    shape.

    Returns the number of buffer rows to allocate on device: ``cfg.rounds``
    normally, ``cfg.ckpt_every`` when host offload is on (the segment stitch
    wraps the shorter buffer as a ring and ``run_federated`` drains it to host
    every segment boundary).  Raises instead of silently OOMing the device
    when the full-horizon buffer would exceed
    ``cfg.score_history_bytes_limit``."""
    if not (cfg.oracle_metrics and cfg.track_scores):
        return None
    full_bytes = int(cfg.rounds) * int(n_clients) * 4  # f32 rows
    if cfg.score_history_host_offload:
        if cfg.ckpt_every <= 0:
            raise ValueError(
                "score_history_host_offload=True needs ckpt_every > 0 (the "
                "device ring holds one segment of score rows); got "
                f"ckpt_every={cfg.ckpt_every}"
            )
        return min(int(cfg.ckpt_every), int(cfg.rounds))
    if full_bytes > cfg.score_history_bytes_limit:
        raise ValueError(
            f"track_scores=True would allocate a ({cfg.rounds}, {n_clients}) "
            f"f32 score-history buffer ({full_bytes / 2**20:.0f} MiB) on "
            f"device, over score_history_bytes_limit="
            f"{cfg.score_history_bytes_limit / 2**20:.0f} MiB.  Set "
            "score_history_host_offload=True (chunked host drain), raise the "
            "limit, or set track_scores=False."
        )
    return int(cfg.rounds)


def _flush_async(params, opt_state, f_state, cfg: FedConfig):
    """End-of-horizon flush of the buffered-async stale-delta ring: apply the
    staleness-discounted sum of every still-pending delta through the server
    optimizer, once, after the last round.  Deterministic in the carried
    buffer state — a preempted-and-resumed run reaches the identical buffer
    and therefore the identical flush (mid-run segment boundaries do NOT
    flush; the buffer rides the carry)."""
    buf = f_state["buf"]
    if not np.asarray(buf["valid"]).any():
        return params
    pending = stragglers.flush_pending(
        buf, cfg.rounds, float(cfg.faults.staleness_discount)
    )
    d_pend = stragglers.vec_to_tree(pending, params)
    params, _ = cfg.server_opt.apply(params, d_pend, opt_state)
    return params


def _derive_keys_step(k, _):
    """One link of the reference loop's chained per-round key derivation:
    ``key, k_data, k_sample = split(key, 3)``.  Both execution paths (and the
    pre-scan history of this repo) consume this identical randomness stream,
    and the segmented runner advances the SAME chain segment by segment."""
    k, kd, ks = jax.random.split(k, 3)
    return k, jnp.stack([kd, ks])


def build_segment_runner(
    task: Task,
    dataset,
    sampler: samplers.Sampler,
    cfg: FedConfig,
    eval_data: tuple | None = None,
    *,
    donate: bool = True,
):
    """The segment-shaped compiled loop: ``(segment_fn, init_state)``.

    ``init_state`` is the canonical ``TrainState`` at round 0 — params/opt/
    sampler freshly initialized from ``cfg.seed``, metric buffers zero-
    preallocated for the full ``cfg.rounds`` horizon — and is also the
    restore template for ``CheckpointManager.restore_or_init``.

    ``segment_fn(state, n_rounds)`` comes from the shared
    ``fed.state.make_segment_fn`` machinery: it derives the next ``n_rounds``
    key pairs from ``state.key`` along the chained split sequence, scans the
    round body over them, and stitches the stacked per-round metrics into the
    (T,)-buffers at offset ``state.round``.  Because the bodies see the same
    carries, keys, and round indices under any segmentation, results are
    bitwise identical for every ``n_rounds`` schedule — a segment boundary is
    pure escape hatch, not a numeric event.

    ``donate=False`` keeps the input state alive across calls (benchmarks
    re-time the same state; donation would invalidate it on non-CPU
    backends)."""
    body = _build_round_body(task, dataset, sampler, cfg, eval_data)
    fault_on = cfg.faults is not None
    ef_on = cfg.compression is not None and bool(cfg.compression.error_feedback)

    key = jax.random.PRNGKey(cfg.seed)
    key, init_key = jax.random.split(key)
    params = task.init(init_key)
    opt_state = cfg.server_opt.init(params)
    s_state = sampler.init()
    f_state = (
        stragglers.fault_state_init(
            cfg.faults, dataset.n_clients, stragglers.flat_dim(params), cfg.compression
        )
        if fault_on
        else ()
    )
    c_state = (
        {"resid": jnp.zeros((stragglers.flat_dim(params),), jnp.float32)}
        if ef_on
        else ()
    )

    carry0 = (params, opt_state, s_state)
    if fault_on:
        carry0 = carry0 + (f_state,)
    if ef_on:
        carry0 = carry0 + (c_state,)
    metrics = init_metric_buffers(
        body,
        carry0,
        (jnp.zeros((), jnp.int32), key, key),
        cfg.rounds,
    )
    score_rows = _score_history_plan(cfg, dataset.n_clients)
    if score_rows is not None and score_rows != cfg.rounds:
        # Host-offload ring: one segment of score rows on device; the rem
        # stitch in make_segment_fn wraps writes into it and run_federated
        # drains it to host at every segment boundary.
        metrics["scores"] = jnp.zeros(
            (score_rows,) + metrics["scores"].shape[1:],
            metrics["scores"].dtype,
        )

    init_state = TrainState(
        params=params,
        opt_state=opt_state,
        sampler=s_state,
        metrics=metrics,
        round=jnp.zeros((), jnp.int32),
        key=key,
        faults=f_state,
        compression=c_state,
    )
    placement = (
        build_placement(init_state, sampler) if sampler.shard is not None else None
    )
    segment = make_segment_fn(
        body, _derive_keys_step,
        with_opt_state=True, with_round_index=True, with_faults=fault_on,
        with_compression=ef_on, donate=donate, placement=placement,
    )
    return segment, init_state


def run_federated(
    task: Task,
    dataset,
    sampler: samplers.Sampler,
    cfg: FedConfig,
    eval_data: tuple | None = None,
    *,
    ckpt_manager=None,
) -> History:
    """Run Algorithm 1; see the module docstring for the execution modes.

    ``ckpt_manager`` (a ``repro.checkpoint.CheckpointManager``, compiled path
    only): restore-or-init from its manifest before running, and publish the
    full ``TrainState`` at every ``cfg.ckpt_every`` segment boundary — a
    preempted run re-invoked with the same config and manager continues from
    the last committed round and produces the identical ``History``."""
    t0 = time.time()

    if cfg.compiled:
        if ckpt_manager is not None and cfg.ckpt_every <= 0:
            # One whole-horizon segment would mean zero mid-run checkpoints —
            # the manager could never protect anything before the final round.
            raise ValueError(
                "run_federated(ckpt_manager=...) needs cfg.ckpt_every > 0; "
                f"got ckpt_every={cfg.ckpt_every}"
            )
        segment, state = build_segment_runner(task, dataset, sampler, cfg, eval_data)
        if ckpt_manager is not None:
            state, _ = ckpt_manager.restore_or_init(state)

        on_segment = None
        offload = (
            cfg.oracle_metrics and cfg.track_scores and cfg.score_history_host_offload
        )
        if offload:
            # Chunked host drain of the (ckpt_every, N) device ring: segments
            # start at multiples of ckpt_every, so each segment's rows sit at
            # the front of the ring.  Rounds executed before a restore (by an
            # earlier process) stay zero — the offloaded history covers this
            # process's rounds.
            scores_host = np.zeros(
                (cfg.rounds, dataset.n_clients),
                np.dtype(state.metrics["scores"].dtype),
            )
            drained_to = int(state.round)

            def on_segment(st, done):
                nonlocal drained_to
                rows = np.asarray(st.metrics["scores"])[: done - drained_to]
                scores_host[drained_to:done] = rows
                drained_to = done

        state = run_segmented(
            state,
            cfg.rounds,
            segment,
            ckpt_every=cfg.ckpt_every,
            manager=ckpt_manager,
            on_segment=on_segment,
        )
        jax.block_until_ready(state)
        params = state.params
        if cfg.faults is not None and int(cfg.faults.async_buffer) > 0:
            params = _flush_async(params, state.opt_state, state.faults, cfg)
        metrics = jax.tree_util.tree_map(np.asarray, state.metrics)
        if offload:
            metrics["scores"] = scores_host
    else:
        key = jax.random.PRNGKey(cfg.seed)
        key, init_key = jax.random.split(key)
        params = task.init(init_key)
        opt_state = cfg.server_opt.init(params)
        s_state = sampler.init()
        fault_on = cfg.faults is not None
        ef_on = cfg.compression is not None and bool(cfg.compression.error_feedback)
        f_state = (
            stragglers.fault_state_init(
                cfg.faults,
                dataset.n_clients,
                stragglers.flat_dim(params),
                cfg.compression,
            )
            if fault_on
            else ()
        )
        c_state = (
            {"resid": jnp.zeros((stragglers.flat_dim(params),), jnp.float32)}
            if ef_on
            else ()
        )

        # Per-round (k_data, k_sample) pairs, derived up front along the same
        # chained-split sequence the segmented runner walks.
        @functools.partial(jax.jit, static_argnames=("rounds",))
        def derive_keys(key, rounds):
            _, pairs = jax.lax.scan(_derive_keys_step, key, None, length=rounds)
            return pairs

        round_keys = derive_keys(key, cfg.rounds)  # (T, 2, key_dim)
        ts = jnp.arange(cfg.rounds, dtype=jnp.int32)

        body = _build_round_body(task, dataset, sampler, cfg, eval_data)
        donate = jax.default_backend() != "cpu"
        step = jax.jit(body, donate_argnums=(0,) if donate else ())
        per_round = []
        for t in range(cfg.rounds):
            carry_in = (params, opt_state, s_state)
            if fault_on:
                carry_in = carry_in + (f_state,)
            if ef_on:
                carry_in = carry_in + (c_state,)
            carry, m = step(
                carry_in,
                (ts[t], round_keys[t, 0], round_keys[t, 1]),
            )
            if ef_on:
                carry, c_state = carry[:-1], carry[-1]
            if fault_on:
                params, opt_state, s_state, f_state = carry
            else:
                params, opt_state, s_state = carry
            # Host sync every round — the reference loop's defining trait.
            per_round.append(jax.tree_util.tree_map(np.asarray, m))
        if fault_on and int(cfg.faults.async_buffer) > 0 and cfg.rounds > 0:
            params = _flush_async(params, opt_state, f_state, cfg)
        if per_round:
            metrics = {k: np.stack([m[k] for m in per_round]) for k in per_round[0]}
        else:
            metrics = {"train_loss": np.zeros(0), "cohort_size": np.zeros(0, np.int32)}
            if fault_on and cfg.faults.deadline is not None:
                metrics["deadline_dropped"] = np.zeros(0, np.int32)
            if not cfg.oracle_metrics:
                metrics["dropped"] = np.zeros(0, np.int32)
            if cfg.oracle_metrics:
                metrics.update(
                    sq_error=np.zeros(0), cost=np.zeros(0), opt_cost=np.zeros(0)
                )
                if cfg.track_scores:
                    metrics["scores"] = np.zeros((0, dataset.n_clients))
            if eval_data is not None:
                metrics["accuracy"] = np.zeros(0)

    hist = _materialize_history(metrics, cfg, has_eval=eval_data is not None)
    hist.final_params = jax.tree_util.tree_map(np.asarray, params)
    hist.wall_time_s = time.time() - t0
    return hist
