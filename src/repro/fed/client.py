"""Client-side local training (Algorithm 1 lines 5-10).

``local_update`` runs R local SGD steps from the broadcast global params and
returns the paper's client update g_i = x^{t,0} - x^{t,R} (NOT the negated
direction: the server applies x <- x - eta_g * d with d the weighted average
of these updates, so g is a descent direction scaled by eta_l).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["local_update"]


def local_update(
    params,
    loss_fn: Callable,
    batches,
    local_lr: float,
):
    """Run R local SGD steps; batches is a pytree with leading axis R.

    Returns (delta, final_loss) where delta = x^{t,0} - x^{t,R}.
    """

    def step(p, batch):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        p = jax.tree_util.tree_map(
            lambda w, g: w - local_lr * g.astype(w.dtype), p, grads
        )
        return p, loss

    final, losses = jax.lax.scan(step, params, batches)
    delta = jax.tree_util.tree_map(lambda a, b: a - b, params, final)
    return delta, losses[-1]


def update_norm(delta) -> jax.Array:
    """||g_i|| over the flattened update pytree (float32 accumulation)."""
    sq = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), delta
    )
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))
