from repro.models import attention, mlp, moe, sharding, ssm, transformer, xlstm
from repro.models.common import ArchConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_caches,
    init_params,
    loss_fn,
    param_count,
    prefill,
)

__all__ = [
    "attention",
    "mlp",
    "moe",
    "sharding",
    "ssm",
    "transformer",
    "xlstm",
    "ArchConfig",
    "decode_step",
    "forward",
    "init_caches",
    "init_params",
    "loss_fn",
    "param_count",
    "prefill",
]
