"""xLSTM blocks (Beck et al. 2024, arXiv:2405.04517): sLSTM and mLSTM.

mLSTM: matrix-memory cell C (hd x hd) with exponential input gate and
stabilizer state m — a gated linear-attention recurrence; parallelizable over
sequence (we use a scan over time; the recurrence state is O(1), which is why
xlstm runs the long_500k decode shape).

sLSTM: scalar-memory cell with hidden-to-gate recurrence (block-diagonal per
head) — inherently sequential; scanned.

Both blocks carry their own projections (the config's d_ff = 0): the mLSTM
block up-projects by 2x with a gated residual; the sLSTM block is followed by
a 4/3-factor gated FFN, matching the reference architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, rms_norm, uniform_init
from repro.models.sharding import shard

__all__ = [
    "mlstm_chunked",
    "init_mlstm",
    "mlstm_block",
    "init_mlstm_state",
    "mlstm_decode_step",
    "init_slstm",
    "slstm_block",
    "init_slstm_state",
    "slstm_decode_step",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ArchConfig, key: jax.Array) -> dict:
    d = cfg.d_model
    d_in = 2 * d  # projection factor 2
    hd = d_in // cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": uniform_init(ks[0], (d, 2 * d_in), cfg.param_dtype),  # -> [x, z]
        "conv_w": uniform_init(ks[1], (cfg.conv_width, d_in), cfg.param_dtype, scale=0.5),
        "wq": uniform_init(ks[2], (d_in, d_in), cfg.param_dtype),
        "wk": uniform_init(ks[3], (d_in, d_in), cfg.param_dtype),
        "wv": uniform_init(ks[4], (d_in, d_in), cfg.param_dtype),
        "w_if": uniform_init(ks[5], (d_in, 2 * cfg.n_heads), cfg.param_dtype),
        "if_bias": jnp.zeros((2 * cfg.n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_in,), cfg.param_dtype),
        "down": uniform_init(ks[6], (d_in, d), cfg.param_dtype),
    }


def _mlstm_cell(q, k, v, i_gate, f_gate):
    """Stabilized mLSTM recurrence.

    q,k,v (B,S,H,hd); gates (B,S,H) pre-activation.
    Returns h (B,S,H,hd).
    """
    bsz, s, h, hd = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,S,H)
    logi = i_gate.astype(jnp.float32)

    def step(carry, inp):
        c, n, m = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        q_t, k_t, v_t, lf, li = inp
        m_new = jnp.maximum(lf + m, li)
        f_s = jnp.exp(lf + m - m_new)[..., None]  # (B,H,1)
        i_s = jnp.exp(li - m_new)[..., None]
        c = c * f_s[..., None] + i_s[..., None] * (
            v_t[..., :, None] * k_t[..., None, :]
        )  # v k^T
        n = n * f_s + i_s * k_t
        denom = jnp.maximum(
            jnp.abs(jnp.sum(n * q_t, axis=-1, keepdims=True)), jnp.exp(-m_new)[..., None]
        )
        h_t = jnp.einsum("bhvk,bhk->bhv", c, q_t) / denom
        return (c, n, m_new), h_t

    scale = hd**-0.5
    xs = (
        jnp.moveaxis(q.astype(jnp.float32) * scale, 1, 0),
        jnp.moveaxis(k.astype(jnp.float32) * scale, 1, 0),
        jnp.moveaxis(v.astype(jnp.float32), 1, 0),
        jnp.moveaxis(logf, 1, 0),
        jnp.moveaxis(logi, 1, 0),
    )
    init = (
        jnp.zeros((bsz, h, hd, hd), jnp.float32),
        jnp.zeros((bsz, h, hd), jnp.float32),
        jnp.full((bsz, h), -jnp.inf, jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1)  # (B,S,H,hd)


def mlstm_block(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    from repro.models.ssm import _causal_conv

    bsz, s, d = x.shape
    d_in = 2 * d
    hd = d_in // cfg.n_heads
    up = x @ params["up"]
    xi, z = up[..., :d_in], up[..., d_in:]
    xc, _ = _causal_conv(xi, params["conv_w"])
    xc = jax.nn.silu(xc)
    q = (xc @ params["wq"]).reshape(bsz, s, cfg.n_heads, hd)
    k = (xc @ params["wk"]).reshape(bsz, s, cfg.n_heads, hd)
    v = (xi @ params["wv"]).reshape(bsz, s, cfg.n_heads, hd)
    q = shard(q, "batch", "seq", "state", None)
    gates = xi @ params["w_if"] + params["if_bias"][None, None]
    i_gate, f_gate = jnp.split(gates.reshape(bsz, s, 2, cfg.n_heads), 2, axis=2)
    if cfg.mlstm_impl == "chunked":
        h, _ = mlstm_chunked(q, k, v, i_gate[:, :, 0], f_gate[:, :, 0], chunk=cfg.mlstm_chunk)
    else:
        h = _mlstm_cell(q, k, v, i_gate[:, :, 0], f_gate[:, :, 0])
    h = h.reshape(bsz, s, d_in).astype(x.dtype)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["down"]


def init_mlstm_state(cfg: ArchConfig, batch: int) -> dict:
    d_in = 2 * cfg.d_model
    hd = d_in // cfg.n_heads
    return {
        "c": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in), jnp.float32),
    }


def mlstm_decode_step(params: dict, cfg: ArchConfig, x: jax.Array, state: dict):
    from repro.models.ssm import _causal_conv

    bsz = x.shape[0]
    d = cfg.d_model
    d_in = 2 * d
    hd = d_in // cfg.n_heads
    up = x @ params["up"]
    xi, z = up[..., :d_in], up[..., d_in:]
    xc, conv_state = _causal_conv(xi, params["conv_w"], state["conv"])
    xc = jax.nn.silu(xc)
    scale = hd**-0.5
    q = (xc @ params["wq"]).reshape(bsz, cfg.n_heads, hd).astype(jnp.float32) * scale
    k = (xc @ params["wk"]).reshape(bsz, cfg.n_heads, hd).astype(jnp.float32) * scale
    v = (xi @ params["wv"]).reshape(bsz, cfg.n_heads, hd).astype(jnp.float32)
    gates = (xi @ params["w_if"] + params["if_bias"][None, None]).astype(jnp.float32)
    gates = gates.reshape(bsz, 2, cfg.n_heads)
    logi, logf = gates[:, 0], jax.nn.log_sigmoid(gates[:, 1])
    m_new = jnp.maximum(logf + state["m"], logi)
    f_s = jnp.exp(logf + state["m"] - m_new)[..., None]
    i_s = jnp.exp(logi - m_new)[..., None]
    c = state["c"] * f_s[..., None] + i_s[..., None] * (v[..., :, None] * k[..., None, :])
    n = state["n"] * f_s + i_s * k
    denom = jnp.maximum(jnp.abs(jnp.sum(n * q, -1, keepdims=True)), jnp.exp(-m_new)[..., None])
    h = jnp.einsum("bhvk,bhk->bhv", c, q) / denom
    h = h.reshape(bsz, 1, d_in).astype(x.dtype)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["down"], {"c": c, "n": n, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(cfg: ArchConfig, key: jax.Array) -> dict:
    d = cfg.d_model
    hd = d // cfg.n_heads
    ks = jax.random.split(key, 6)
    d_ff = int(d * 4 / 3)
    return {
        "w_in": uniform_init(ks[0], (d, 4 * d), cfg.param_dtype),  # i,f,z,o pre-acts
        "r": uniform_init(ks[1], (cfg.n_heads, hd, 4 * hd), cfg.param_dtype),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "norm_scale": jnp.zeros((d,), cfg.param_dtype),
        "ffn_gate": uniform_init(ks[2], (d, d_ff), cfg.param_dtype),
        "ffn_up": uniform_init(ks[3], (d, d_ff), cfg.param_dtype),
        "ffn_down": uniform_init(ks[4], (d_ff, d), cfg.param_dtype),
    }


def _slstm_gates(pre, h_prev, params, n_heads, hd):
    """pre (B,4d) input pre-activations; recurrent contribution from h_prev."""
    bsz = pre.shape[0]
    rec = jnp.einsum(
        "bhk,hkg->bhg", h_prev.reshape(bsz, n_heads, hd), params["r"].astype(jnp.float32)
    ).reshape(bsz, 4 * n_heads * hd)
    return pre + rec


def _slstm_cell(params, x_pre, n_heads, hd, segment: int = 0):
    """x_pre (B,S,4d). Returns h (B,S,d).

    segment > 0 applies segment-level gradient checkpointing: the backward
    pass saves recurrent state only at segment boundaries and recomputes the
    (cheap, elementwise) cell within — cutting the per-token HBM state
    traffic of the inherently-sequential sLSTM by ~segment x
    (EXPERIMENTS.md section Perf, xlstm iteration 4)."""
    bsz, s, d4 = x_pre.shape
    d = d4 // 4

    def step(carry, pre_t):
        c, n, m, h_prev = carry
        g = _slstm_gates(pre_t.astype(jnp.float32), h_prev, params, n_heads, hd)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)  # (B,d) each
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        i_s = jnp.exp(gi - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * jnp.tanh(gz)
        n = f_s * n + i_s
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    z = jnp.zeros((bsz, d), jnp.float32)
    init = (z, z, jnp.full((bsz, d), -1e30, jnp.float32), z)
    xs = jnp.moveaxis(x_pre, 1, 0)  # (S, B, 4d)
    if segment and s % segment == 0 and s > segment:
        n_seg = s // segment

        @jax.checkpoint
        def seg_body(carry, xs_seg):
            carry, hs = jax.lax.scan(step, carry, xs_seg)
            return carry, hs

        _, hs = jax.lax.scan(seg_body, init, xs.reshape(n_seg, segment, bsz, d4))
        hs = hs.reshape(s, bsz, d)
    else:
        _, hs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1)


def slstm_block(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    bsz, s, d = x.shape
    hd = d // cfg.n_heads
    pre = x @ params["w_in"] + params["bias"][None, None]
    h = _slstm_cell(params, pre, cfg.n_heads, hd, segment=cfg.slstm_segment).astype(x.dtype)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    ff = (h @ params["ffn_up"]) * jax.nn.silu(h @ params["ffn_gate"])
    return ff @ params["ffn_down"]


def init_slstm_state(cfg: ArchConfig, batch: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), -1e30, jnp.float32), "h": z}


def slstm_decode_step(params: dict, cfg: ArchConfig, x: jax.Array, state: dict):
    bsz = x.shape[0]
    d = cfg.d_model
    hd = d // cfg.n_heads
    pre = (x[:, 0] @ params["w_in"] + params["bias"][None]).astype(jnp.float32)
    g = _slstm_gates(pre, state["h"], params, cfg.n_heads, hd)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + state["m"], gi)
    i_s = jnp.exp(gi - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    c = f_s * state["c"] + i_s * jnp.tanh(gz)
    n = f_s * state["n"] + i_s
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    hx = rms_norm(h[:, None].astype(x.dtype), params["norm_scale"], cfg.norm_eps)
    ff = (hx @ params["ffn_up"]) * jax.nn.silu(hx @ params["ffn_gate"])
    return ff @ params["ffn_down"], {"c": c, "n": n, "m": m_new, "h": h}


# ---------------------------------------------------------------------------
# chunkwise-parallel mLSTM (EXPERIMENTS.md section Perf, xlstm iteration)
# ---------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int = 128):
    """Chunkwise-parallel stabilized mLSTM — same math as _mlstm_cell.

    With LF'_t the chunk-local cumulative log-forget and a'_s = li_s - LF'_s,
    the cell's running stabilizer is m_t = LF'_t + M_t with
    M_t = max(m_in, cummax(a')_t), and the m-normalized unrolled weights are
    w[t,s] = exp(a'_s - M_t) — so each chunk is two MXU GEMMs over a (Q, Q)
    decay matrix plus a rank-1-free state contribution; the (hd x hd) matrix
    state and normalizer are carried only at CHUNK boundaries.  The
    sequential cell writes that state to HBM every token — this is the
    TPU-native schedule (and the target of a future Pallas kernel mirroring
    kernels/ssd_scan).

    q,k,v (B,S,H,hd) — q,k pre-scaled by hd^-0.5 like _mlstm_cell's inputs;
    gates (B,S,H) pre-activation.  Returns (h (B,S,H,hd), (C~, n~, m)).
    """
    bsz, s, h, hd = q.shape
    qc = min(chunk, s)
    while s % qc:
        qc //= 2
    nc = s // qc

    scale = hd**-0.5
    qf = (q.astype(jnp.float32) * scale).reshape(bsz, nc, qc, h, hd)
    kf = (k.astype(jnp.float32) * scale).reshape(bsz, nc, qc, h, hd)
    vf = v.astype(jnp.float32).reshape(bsz, nc, qc, h, hd)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32)).reshape(bsz, nc, qc, h)
    li = i_gate.astype(jnp.float32).reshape(bsz, nc, qc, h)

    lf_cum = jnp.cumsum(lf, axis=2)  # LF'_t inclusive (B,nc,Q,H)
    a = li - lf_cum  # a'_s (B,nc,Q,H)
    causal = jnp.tril(jnp.ones((qc, qc), bool))

    def body(carry, xs):
        c_in, n_in, m_in = carry  # (B,H,v,k), (B,H,k), (B,H)
        q_c, k_c, v_c, lfc_c, a_c = xs  # (B,Q,H,hd) / (B,Q,H)
        m_big = jnp.maximum(jax.lax.cummax(a_c, axis=1), m_in[:, None, :])  # (B,Q,H)
        # intra-chunk weights w[t,s] = exp(a'_s - M_t), s <= t
        d = jnp.exp(a_c[:, None, :, :] - m_big[:, :, None, :])  # (B,t,s,H)
        d = jnp.where(causal[None, :, :, None], d, 0.0)
        qk = jnp.einsum("bthd,bshd->btsh", q_c, k_c)
        num = jnp.einsum("btsh,bshd->bthd", qk * d, v_c)
        inter = jnp.exp(m_in[:, None, :] - m_big)  # (B,t,H)
        num = num + inter[..., None] * jnp.einsum("bthk,bhvk->bthv", q_c, c_in)
        n_vec = jnp.einsum("btsh,bshd->bthd", d, k_c) + inter[..., None] * n_in[:, None]
        m_t = lfc_c + m_big  # (B,Q,H)
        denom = jnp.maximum(jnp.abs(jnp.sum(n_vec * q_c, axis=-1)), jnp.exp(-m_t))
        h_c = num / denom[..., None]

        # chunk-exit state (normalized by exp(m at chunk end))
        m_end = m_big[:, -1]  # (B,H)
        w_exit = jnp.exp(a_c - m_end[:, None, :])  # (B,s,H)
        c_out = jnp.einsum("bsh,bshv,bshk->bhvk", w_exit, v_c, k_c)
        n_out = jnp.einsum("bsh,bshk->bhk", w_exit, k_c)
        keep = jnp.exp(m_in - m_end)
        c_out = c_out + keep[..., None, None] * c_in
        n_out = n_out + keep[..., None] * n_in
        m_next = lfc_c[:, -1] + m_end  # cell-equivalent m at chunk end
        return (c_out, n_out, m_next), h_c

    init = (
        jnp.zeros((bsz, h, hd, hd), jnp.float32),
        jnp.zeros((bsz, h, hd), jnp.float32),
        jnp.full((bsz, h), -1e30, jnp.float32),
    )
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, lf_cum, a))
    carry, hs = jax.lax.scan(body, init, xs)
    out = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, h, hd)
    return out.astype(q.dtype), carry
