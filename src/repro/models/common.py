"""Shared model-definition machinery: the generic ArchConfig and primitives.

One configuration dataclass describes every assigned architecture (dense,
MoE, SSM, hybrid, enc-dec audio, VLM).  Block kinds are composed via
``block_pattern`` which is cycled across the layer stack; parameters for a
homogeneous stack are *stacked along a leading layer axis* and executed with
``jax.lax.scan`` so tracing/compile cost is O(pattern), not O(n_layers) —
essential for the 126-layer 405B dry-run on a CPU host.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "rms_norm", "apply_rope", "rope_angles", "softcap", "uniform_init"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads

    # layer composition: cycled across layers; len must divide n_layers
    block_pattern: tuple[str, ...] = ("attn",)

    # attention details
    rope_theta: float = 10000.0
    sliding_window: int | None = None  # used by "attn_local" blocks
    attn_softcap: float | None = None  # gemma2 attention-logit soft capping
    final_softcap: float | None = None  # gemma2 output-logit soft capping
    qk_norm: bool = False  # qwen3 per-head q/k RMSNorm

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # SSM (Mamba2 / xLSTM)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0  # zamba2: shared attention block cadence

    # encoder-decoder / multimodal
    encoder_layers: int = 0  # whisper encoder depth
    cross_attn_every: int = 0  # vlm: every k-th layer is a cross-attn block
    frontend: str | None = None  # "audio" | "vision" (stubbed embeddings)
    frontend_seq: int = 0  # number of frames / image patches
    frontend_dim: int = 0  # embedding dim delivered by the stubbed frontend
    scale_embed: bool = False  # gemma2: h *= sqrt(d_model)

    # numerics
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = True

    # federated execution (DESIGN.md section 3)
    round_mode: str = "client_parallel"  # or "cohort_sequential"
    long_context_ok: bool = False  # sub-quadratic decode supported
    remat: str = "full"  # "full" | "none" — checkpoint the layer-scan body
    attn_impl: str = "einsum"  # "einsum" | "chunked" (online-softmax over KV
    # blocks — the jnp realization of kernels/flash_attention; O(S) memory)
    moe_impl: str = "dense"  # "dense" (GSPMD scatter dispatch) | "a2a"
    # (shard_map all-to-all dispatch; requires a mesh context + tokens
    # sharded (batch->data, seq->model); cohort_sequential archs only)
    mlstm_impl: str = "scan"  # "scan" (per-step cell) | "chunked"
    mlstm_chunk: int = 128  # chunk length for the chunked mLSTM
    slstm_segment: int = 0  # >0: segment-remat the sLSTM scan (saves only
    # every segment-th state for backward; recomputes within segments)
    # (chunkwise-parallel stabilized form: MXU GEMMs per chunk, states only
    # at chunk boundaries — the TPU-native mLSTM, see xlstm.mlstm_chunked)

    # provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    def pattern_repeats(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: pattern {self.block_pattern} must divide {self.n_layers} layers"
        )
        return self.n_layers // len(self.block_pattern)

    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        n_pat = len(self.block_pattern)
        small = dict(
            n_layers=max(n_pat, 2 if n_pat == 1 else n_pat),
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=32 if self.head_dim else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            # dropless at test scale: capacity >= E/k covers the worst-case
            # routing so prefill+decode agree exactly with the full forward
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            frontend_seq=min(self.frontend_seq, 16) if self.frontend_seq else 0,
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            param_dtype=jnp.float32,
            name=self.name + "-reduced",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embeddings at given integer positions."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (seq, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    while cos.ndim < x1.ndim:
        cos = cos[..., None, :] if cos.ndim == x1.ndim - 1 else cos[None]
        sin = sin[..., None, :] if sin.ndim == x1.ndim - 1 else sin[None]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


def uniform_init(key: jax.Array, shape, dtype, scale: float | None = None) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0) * scale).astype(dtype)
