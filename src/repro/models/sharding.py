"""Logical-axis sharding annotations for model code.

Model forward functions annotate intermediates with *logical* axis names
(``shard(x, "batch", "seq", "heads", None)``).  Outside a mesh context this is
a no-op (CPU smoke tests); inside ``use_rules`` the names map to mesh axes and
become ``with_sharding_constraint``s that steer GSPMD on the production mesh.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["shard", "use_rules", "DEFAULT_RULES", "current_mesh"]

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_rules", default=None)

# logical axis -> mesh axis (or tuple of mesh axes). Overridden per-mesh in
# launch/sharding.py; these defaults match the single-pod (data, model) mesh.
DEFAULT_RULES = {
    "batch": ("data",),
    "clients": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "embed": None,
    "seq": None,
    "kv_seq": ("model",),  # decode-time KV cache sequence sharding
    "state": ("model",),  # SSM recurrent state heads
}


@contextlib.contextmanager
def use_rules(mesh, rules: dict | None = None):
    token = _CTX.set((mesh, dict(DEFAULT_RULES, **(rules or {}))))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh():
    ctx = _CTX.get()
    return None if ctx is None else ctx[0]


def shard(x: jax.Array, *logical_axes):
    """Constrain `x` so logical_axes[i] governs dimension i (None = replicated)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    mesh_axes = []
    used: set = set()
    for name in logical_axes:
        axes = None if name is None else rules.get(name)
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            # a mesh axis can shard at most one dim: first logical axis wins
            if any(a in used for a in flat):
                axes = None
            else:
                used.update(flat)
        mesh_axes.append(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*mesh_axes)))
