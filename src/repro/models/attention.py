"""Grouped-query attention with RoPE, soft-capping, sliding windows,
cross-attention, and cached decode — the attention substrate for every
assigned architecture.

Layout conventions:
  activations    (B, S, d_model)
  q              (B, S, KV, G, hd)   G = n_heads / n_kv_heads
  k, v           (B, S, KV, hd)
  decode cache   {"k": (B, S_max, KV, hd), "v": ..., "idx": ()}
  paged cache    {"pool_k": (B*P, page, KV, hd), "pool_v": ...,
                  "page_table": (B, P) int32}

The paged layout is the serving substrate (``repro.serve``): the KV pool is
one preallocated static-shape buffer, sequences address it through an int32
page table, and a single-token decode writes exactly one (page, slot) line —
so the decode program's avals never depend on how long a sequence has grown
and the jit cache stays at one entry for the server's whole lifetime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, apply_rope, rms_norm, rope_angles, softcap, uniform_init
from repro.models.sharding import shard

__all__ = [
    "init_attention",
    "attention",
    "cross_attention",
    "init_kv_cache",
    "decode_attention",
    "init_paged_kv_cache",
    "pack_kv_to_pages",
    "paged_decode_attention",
]

_NEG = -2.3819763e38  # bf16-safe -inf surrogate


def init_attention(cfg: ArchConfig, key: jax.Array, cross: bool = False) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": uniform_init(ks[0], (cfg.d_model, cfg.n_heads * hd), cfg.param_dtype),
        "wk": uniform_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), cfg.param_dtype),
        "wv": uniform_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), cfg.param_dtype),
        "wo": uniform_init(ks[3], (cfg.n_heads * hd, cfg.d_model), cfg.param_dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_scale"] = jnp.zeros((hd,), cfg.param_dtype)
        p["k_scale"] = jnp.zeros((hd,), cfg.param_dtype)
    return p


def _project_qkv(params, cfg: ArchConfig, xq: jax.Array, xkv: jax.Array):
    b, s_q, _ = xq.shape
    s_kv = xkv.shape[1]
    hd = cfg.hd
    q = (xq @ params["wq"]).reshape(b, s_q, cfg.n_kv_heads, cfg.q_groups, hd)
    k = (xkv @ params["wk"]).reshape(b, s_kv, cfg.n_kv_heads, hd)
    v = (xkv @ params["wv"]).reshape(b, s_kv, cfg.n_kv_heads, hd)
    if "q_scale" in params:
        q = rms_norm(q, params["q_scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_scale"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg: ArchConfig, q, k, v, mask):
    """q (B,Sq,KV,G,hd); k,v (B,Skv,KV,hd); mask broadcastable (B,1,1,Sq,Skv)."""
    if cfg.attn_impl == "chunked" and k.shape[1] >= 512:
        return _sdpa_chunked(cfg, q, k, v, mask)
    scale = cfg.hd ** -0.5
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    logits = softcap(logits, cfg.attn_softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def _sdpa_chunked(cfg: ArchConfig, q, k, v, mask, block: int = 512):
    """Online-softmax attention over KV blocks (flash-attention dataflow in
    pure jnp — the TPU Pallas kernel's fallback).  The (Sq, Skv) probability
    matrix is never materialized: HBM traffic drops from O(S^2) to O(S*hd).
    """
    b, s_q, kv, g, hd = q.shape
    s_k = k.shape[1]
    blk = block
    while s_k % blk:
        blk //= 2
    n_blocks = s_k // blk
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    def body(carry, i):
        acc, m_prev, l_prev = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, i * blk, blk, 1).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(v, i * blk, blk, 1).astype(jnp.float32)
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_blk)
        logits = softcap(logits, cfg.attn_softcap)
        if mask is not None:
            m_blk = jax.lax.dynamic_slice_in_dim(
                jnp.broadcast_to(mask, mask.shape[:-1] + (s_k,)), i * blk, blk, -1
            )
            logits = jnp.where(m_blk, logits, _NEG)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, v_blk)
        return (acc, m_new, l_new), ()

    acc0 = jnp.zeros((b, kv, g, s_q, hd), jnp.float32)
    m0 = jnp.full((b, kv, g, s_q), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s_q), jnp.float32)
    (acc, m_fin, l_fin), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(n_blocks)
    )
    safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
    out = acc / safe[..., None]
    return jnp.moveaxis(out, 3, 1).astype(v.dtype)  # (B,Sq,KV,G,hd)


def _causal_mask(s_q: int, s_kv: int, window: int | None, offset: int = 0):
    """(1,1,1,Sq,Skv) bool; offset = absolute position of query 0."""
    qpos = jnp.arange(s_q)[:, None] + offset
    kpos = jnp.arange(s_kv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = jnp.logical_and(m, kpos > qpos - window)
    return m[None, None, None]


def attention(
    params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Full-sequence self-attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, x)
    pos = jnp.arange(s)
    cos, sin = rope_angles(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos[None, :, None, None, :], sin[None, :, None, None, :])
    k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
    q = shard(q, "batch", "seq", "kv_heads", None, None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    mask = _causal_mask(s, s, window) if causal else None
    out = _sdpa(cfg, q, k, v, mask)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    return out @ params["wo"]


def cross_attention(params, cfg: ArchConfig, x: jax.Array, kv_source: jax.Array) -> jax.Array:
    """Cross-attention to encoder / image embeddings (no RoPE, full mask)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, kv_source)
    out = _sdpa(cfg, q, k, v, mask=None)
    return out.reshape(b, s, cfg.n_heads * cfg.hd) @ params["wo"]


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.param_dtype
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def decode_attention(
    params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: dict,
    index: jax.Array,
    *,
    window: int | None = None,
) -> tuple[jax.Array, dict]:
    """Single-token decode: x (B,1,d); cache holds `index` valid tokens.

    The cache sequence axis carries the "kv_seq" logical sharding (mapped to
    the `model` mesh axis for long-context decode): the q@k contraction and
    the probs@v contraction then reduce over a sharded axis, which GSPMD
    lowers to per-shard partial attention + a small cross-shard combine —
    exactly the flash-decode communication pattern (DESIGN.md section 3).
    """
    b, one, _ = x.shape
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    cos, sin = rope_angles(index[None], cfg.hd, cfg.rope_theta)  # (1, hd/2)
    q = apply_rope(q, cos[None, :, None, None, :], sin[None, :, None, None, :])
    k_new = apply_rope(k_new, cos[None, :, None, :], sin[None, :, None, :])

    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0))
    k = shard(k, "batch", "kv_seq", None, None)
    v = shard(v, "batch", "kv_seq", None, None)

    s_max = k.shape[1]
    kpos = jnp.arange(s_max)
    valid = kpos <= index
    if window is not None:
        valid = jnp.logical_and(valid, kpos > index - window)
    mask = valid[None, None, None, None, :]
    out = _sdpa(cfg, q, k, v, mask)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
    return out @ params["wo"], {"k": k, "v": v}


# ---------------------------------------------------------------------------
# paged decode cache (the serving layout)
# ---------------------------------------------------------------------------


def _pages_per_seq(max_seq: int, page_size: int) -> int:
    return -(-int(max_seq) // int(page_size))


def init_paged_kv_cache(
    cfg: ArchConfig, batch: int, max_seq: int, page_size: int, dtype=None
) -> dict:
    """Preallocated paged KV cache: a (B*P, page, KV, hd) pool plus a
    (B, P) int32 page table mapping each sequence's logical pages onto pool
    rows.  The identity table assigns every sequence a contiguous stripe;
    the indirection is what a production server remaps for prefix sharing /
    admission — the decode program below only ever sees the table."""
    dtype = dtype or cfg.param_dtype
    pages = _pages_per_seq(max_seq, page_size)
    pool = (batch * pages, int(page_size), cfg.n_kv_heads, cfg.hd)
    table = jnp.arange(batch * pages, dtype=jnp.int32).reshape(batch, pages)
    return {
        "pool_k": jnp.zeros(pool, dtype),
        "pool_v": jnp.zeros(pool, dtype),
        "page_table": table,
    }


def pack_kv_to_pages(cache: dict, page_size: int) -> dict:
    """Repack a dense prefill cache ``{"k","v"}: (B, S_max, KV, hd)`` into the
    paged layout (identity page table).  This is the prefill->decode hand-off:
    prefill writes the cheap contiguous layout, one reshape moves it into the
    pool the decode step indexes through the table."""
    k, v = cache["k"], cache["v"]
    b, s_max, kv, hd = k.shape
    pages = _pages_per_seq(s_max, page_size)
    pad = pages * int(page_size) - s_max
    if pad:
        k = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)])
    table = jnp.arange(b * pages, dtype=jnp.int32).reshape(b, pages)
    return {
        "pool_k": k.reshape(b * pages, int(page_size), kv, hd),
        "pool_v": v.reshape(b * pages, int(page_size), kv, hd),
        "page_table": table,
    }


def paged_decode_attention(
    params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: dict,
    index: jax.Array,
    *,
    window: int | None = None,
) -> tuple[jax.Array, dict]:
    """Single-token decode against the paged cache (lockstep batch: every
    sequence writes position ``index``).

    The new K/V line lands in exactly one (page, slot) per sequence: the
    physical page comes from one dynamic row of the page table, the write is
    a (B,)-scatter into the pool — O(B * KV * hd) bytes touched regardless of
    context length, versus the dense path's full-cache ``dynamic_update_slice``
    copy when the carry is not donated.  Attention then gathers the table's
    view of the pool back to (B, P*page, KV, hd) and reuses the masked SDPA
    (positions past ``index`` — including the padded tail of the last page —
    are masked, so pool garbage never contributes)."""
    b, _one, _ = x.shape
    pool_k, pool_v, table = cache["pool_k"], cache["pool_v"], cache["page_table"]
    page_size = pool_k.shape[1]

    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    cos, sin = rope_angles(index[None], cfg.hd, cfg.rope_theta)  # (1, hd/2)
    q = apply_rope(q, cos[None, :, None, None, :], sin[None, :, None, None, :])
    k_new = apply_rope(k_new, cos[None, :, None, :], sin[None, :, None, :])

    # index is traced: page/slot stay inside the jitted program (no host sync,
    # no shape dependence on sequence length — the compile-once contract).
    page = index // page_size
    slot = index % page_size
    phys = jax.lax.dynamic_index_in_dim(table, page, axis=1, keepdims=False)  # (B,)
    pool_k = pool_k.at[phys, slot].set(k_new[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[phys, slot].set(v_new[:, 0].astype(pool_v.dtype))

    # (B, P, page, KV, hd) -> (B, P*page, KV, hd): the table's sequence view.
    pages = table.shape[1]
    k = pool_k[table].reshape(b, pages * page_size, *pool_k.shape[2:])
    v = pool_v[table].reshape(b, pages * page_size, *pool_v.shape[2:])
    k = shard(k, "batch", "kv_seq", None, None)
    v = shard(v, "batch", "kv_seq", None, None)

    kpos = jnp.arange(pages * page_size)
    valid = kpos <= index
    if window is not None:
        valid = jnp.logical_and(valid, kpos > index - window)
    mask = valid[None, None, None, None, :]
    out = _sdpa(cfg, q, k, v, mask)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
    return out @ params["wo"], {
        "pool_k": pool_k,
        "pool_v": pool_v,
        "page_table": table,
    }
