"""Mamba2 (SSD) block — TPU-native chunked-scan formulation.

The CUDA selective-scan does not transfer to TPU; the SSD duality does
(Dao & Gu 2024): within a chunk the recurrence is a small quadratic attention
(MXU-shaped GEMMs), across chunks a cheap recurrence over per-chunk summary
states.  The chunked path below is what trains/lowers; a step recurrence
serves decode (O(1) state per token — this is why zamba2/xlstm run the
long_500k shape).  ``repro.kernels.ssd_scan`` carries the Pallas version of
the intra-chunk kernel with ``repro.kernels.ref`` as the oracle.

State-space shapes (n_groups = 1, B/C shared across heads):
  x   (B, S, H, hd)      dt (B, S, H)       A  (H,) negative scalars
  B,C (B, S, N)          chunk summary state (B, H, hd, N)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, rms_norm, uniform_init
from repro.models.sharding import shard

__all__ = [
    "init_mamba2",
    "mamba2_block",
    "mamba2_decode_step",
    "init_mamba2_state",
    "ssd_chunked",
]


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int = 128, return_state: bool = False):
    """SSD scan. x (B,S,H,hd); dt (B,S,H); a_log (H,); b,c (B,S,N).

    Returns y (B,S,H,hd), and the final recurrent state (B,H,hd,N) when
    ``return_state`` (used by prefill — no O(S) sequential replay needed).
    """
    bsz, s, h, hd = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    af = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative
    dtf = jax.nn.softplus(dt.astype(jnp.float32))  # (B,S,H)
    xa = x.astype(jnp.float32) * dtf[..., None]  # dt-weighted input
    da = dtf * af  # (B,S,H) log-decay per step (negative)

    xa = xa.reshape(bsz, nc, chunk, h, hd)
    da = da.reshape(bsz, nc, chunk, h)
    bm = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cm = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    cum = jnp.cumsum(da, axis=2)  # (B,nc,Q,H) inclusive cumulative log decay
    # intra-chunk quadratic term: M[t,s] = exp(cum_t - cum_s) for s <= t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqn,bcsn->bcqs", cm, bm)  # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcqs,bcqsh,bcshd->bcqhd", cb, m, xa)

    # chunk summary states: S_c = sum_s exp(cum_last - cum_s) * B_s x_s^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshd->bchdn", bm, decay_to_end, xa)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def step(h_prev, inp):
        s_c, dec = inp  # (B,H,hd,N), (B,H)
        h_new = h_prev * dec[:, :, None, None] + s_c
        return h_new, h_prev  # emit the *incoming* state for chunk c

    h0 = jnp.zeros((bsz, h, hd, n), jnp.float32)
    h_last, h_in = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,hd,N) state entering each chunk

    # inter-chunk contribution: y_t += C_t . (exp(cum_t) * h_in)
    y_inter = jnp.einsum("bcqn,bcqh,bchdn->bcqhd", cm, jnp.exp(cum), h_in)

    y = (y_intra + y_inter).reshape(bsz, s, h, hd)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    if return_state:
        return y.astype(x.dtype), h_last
    return y.astype(x.dtype)


def init_mamba2(cfg: ArchConfig, key: jax.Array) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n_heads = d_in // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (d_in), x (d_in), B (n), C (n), dt (H)]
        "in_proj": uniform_init(ks[0], (d, 2 * d_in + 2 * n + n_heads), cfg.param_dtype),
        "conv_w": uniform_init(ks[1], (cfg.conv_width, conv_ch), cfg.param_dtype, scale=0.5),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_in,), cfg.param_dtype),
        "out_proj": uniform_init(ks[2], (d_in, d), cfg.param_dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x (B,S,C); w (W,C). state (B,W-1,C) for decode."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    # keep the carried dtype stable across scan iterations (prefill replay)
    new_state = xp[:, -(width - 1) :, :]
    if state is not None:
        new_state = new_state.astype(state.dtype)
    return out, new_state


def _split_proj(cfg: ArchConfig, proj):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    n_heads = d_in // cfg.ssm_head_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in : 2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n :]
    return z, xbc, dt, d_in, n, n_heads


def mamba2_block(
    params: dict, cfg: ArchConfig, x: jax.Array, chunk: int = 128, return_state: bool = False
):
    bsz, s, d = x.shape
    proj = x @ params["in_proj"]
    z, xbc_raw, dt, d_in, n, n_heads = _split_proj(cfg, proj)
    xbc, conv_tail = _causal_conv(xbc_raw, params["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(bsz, s, n_heads, cfg.ssm_head_dim)
    b = xbc[..., d_in : d_in + n]
    c = xbc[..., d_in + n :]
    xs = shard(xs, "batch", "seq", "state", None)
    dt = dt + params["dt_bias"][None, None, :]
    ch = min(chunk, s)
    while s % ch:
        ch //= 2
    out = ssd_chunked(
        xs, dt, params["a_log"], b, c, params["d_skip"], chunk=max(ch, 1),
        return_state=return_state,
    )
    y, ssm_state = out if return_state else (out, None)
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    y = y @ params["out_proj"]
    if return_state:
        return y, {"conv": conv_tail, "ssm": ssm_state}
    return y


def init_mamba2_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_head_dim, n), jnp.float32),
    }


def mamba2_decode_step(params: dict, cfg: ArchConfig, x: jax.Array, state: dict):
    """x (B,1,d) -> (y (B,1,d), new_state). O(1) per token."""
    bsz = x.shape[0]
    proj = x @ params["in_proj"]
    z, xbc, dt, d_in, n, n_heads = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], state["conv"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(bsz, n_heads, cfg.ssm_head_dim)
    b = xbc[:, 0, d_in : d_in + n]  # (B,N)
    c = xbc[:, 0, d_in + n :]
    dtf = jax.nn.softplus((dt[:, 0] + params["dt_bias"][None]).astype(jnp.float32))  # (B,H)
    af = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dtf * af[None])  # (B,H)
    h = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bhd,bn,bh->bhdn", xs.astype(jnp.float32), b.astype(jnp.float32), dtf
    )
    y = jnp.einsum("bhdn,bn->bhd", h, c.astype(jnp.float32))
    y = y + params["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": h}
