"""Gated / plain MLP blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, uniform_init
from repro.models.sharding import shard

__all__ = ["init_mlp", "mlp"]

_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(cfg: ArchConfig, key: jax.Array, d_ff: int | None = None, gated: bool = True) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "up": uniform_init(ks[0], (cfg.d_model, d_ff), cfg.param_dtype),
        "down": uniform_init(ks[1], (d_ff, cfg.d_model), cfg.param_dtype),
    }
    if gated:
        p["gate"] = uniform_init(ks[2], (cfg.d_model, d_ff), cfg.param_dtype)
    return p


def mlp(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    act = _ACT[cfg.act]
    h = x @ params["up"]
    if "gate" in params:
        h = h * act(x @ params["gate"])
    else:
        h = act(h)
    h = shard(h, "batch", "seq", "ffn")
    return h @ params["down"]
