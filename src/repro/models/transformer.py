"""Model assembly: pattern-scanned block stacks for every assigned arch.

Parameters for each pattern *slot* are stacked along a leading repeat axis and
executed with ``jax.lax.scan`` over groups, so the traced graph size is
O(len(pattern)) regardless of depth (126-layer llama3-405b traces as one
layer group).  Three execution modes share the block implementations:

  train   — full-sequence forward, no caches              -> logits, aux
  prefill — full-sequence forward, caches returned        -> logits, caches
  decode  — one token, caches consumed/updated            -> logits, caches

Caches are pytrees mirroring the slot structure (stacked along repeats), so
they scan in lock-step with the parameters.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import (
    _causal_mask,
    _project_qkv,
    _sdpa,
    decode_attention,
    init_attention,
)
from repro.models.common import ArchConfig, apply_rope, rms_norm, rope_angles, softcap, uniform_init
from repro.models.mlp import init_mlp, mlp
from repro.models.sharding import shard

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_caches",
    "param_count",
]

MOE_AUX_COEF = 0.01

ATTN_KINDS = {"attn", "attn_local", "moe", "shared_attn", "dec"}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(kind: str, cfg: ArchConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    dt = cfg.param_dtype
    if kind in ("attn", "attn_local"):
        return {
            "ln1": jnp.zeros((d,), dt),
            "attn": init_attention(cfg, ks[0]),
            "ln2": jnp.zeros((d,), dt),
            "mlp": init_mlp(cfg, ks[1]),
        }
    if kind == "moe":
        return {
            "ln1": jnp.zeros((d,), dt),
            "attn": init_attention(cfg, ks[0]),
            "ln2": jnp.zeros((d,), dt),
            "moe": moe_mod.init_moe(cfg, ks[1]),
        }
    if kind == "mamba2":
        return {"ln1": jnp.zeros((d,), dt), "ssm": ssm_mod.init_mamba2(cfg, ks[0])}
    if kind == "mlstm":
        return {"ln1": jnp.zeros((d,), dt), "cell": xlstm_mod.init_mlstm(cfg, ks[0])}
    if kind == "slstm":
        return {"ln1": jnp.zeros((d,), dt), "cell": xlstm_mod.init_slstm(cfg, ks[0])}
    if kind == "cross_attn":
        return {
            "ln1": jnp.zeros((d,), dt),
            "attn": init_attention(cfg, ks[0], cross=True),
            "ln2": jnp.zeros((d,), dt),
            "mlp": init_mlp(cfg, ks[1]),
            "gate": jnp.zeros((), dt),  # llama-vision gated cross-attn
        }
    if kind == "enc":  # whisper encoder block (bidirectional)
        return {
            "ln1": jnp.zeros((d,), dt),
            "attn": init_attention(cfg, ks[0]),
            "ln2": jnp.zeros((d,), dt),
            "mlp": init_mlp(cfg, ks[1], gated=False),
        }
    if kind == "dec":  # whisper decoder block (self + cross)
        return {
            "ln1": jnp.zeros((d,), dt),
            "attn": init_attention(cfg, ks[0]),
            "lnx": jnp.zeros((d,), dt),
            "xattn": init_attention(cfg, ks[1], cross=True),
            "ln2": jnp.zeros((d,), dt),
            "mlp": init_mlp(cfg, ks[2], gated=False),
        }
    if kind == "shared_attn":
        return {}  # weights live in params["shared"], invoked by closure
    raise ValueError(f"unknown block kind {kind!r}")


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, 8)
    reps = cfg.pattern_repeats()
    params: dict[str, Any] = {
        "embed": uniform_init(keys[0], (cfg.vocab, cfg.d_model), cfg.param_dtype, scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    # stacked per-slot parameters
    stacks = []
    for j, kind in enumerate(cfg.block_pattern):
        slot_keys = jax.random.split(jax.random.fold_in(keys[1], j), reps)
        stacked = jax.vmap(lambda k, kind=kind: _init_block(kind, cfg, k))(slot_keys)
        stacks.append(stacked)
    params["stacks"] = stacks

    if "shared_attn" in cfg.block_pattern:
        params["shared"] = _init_block("attn", cfg, keys[2])

    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "pos": uniform_init(
                keys[4], (cfg.frontend_seq, cfg.d_model), cfg.param_dtype, scale=0.02
            ),
            "stack": jax.vmap(lambda k: _init_block("enc", cfg, k))(enc_keys),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        }
    if cfg.frontend:
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = uniform_init(keys[5], (fd, cfg.d_model), cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = uniform_init(keys[6], (cfg.d_model, cfg.vocab), cfg.param_dtype, scale=0.02)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# block application (shared across modes)
# ---------------------------------------------------------------------------


def _apply_block(
    kind: str,
    p: dict,
    cfg: ArchConfig,
    h: jax.Array,
    *,
    mode: str,
    cache: Any = None,
    index: jax.Array | None = None,
    cross_src: jax.Array | None = None,
    shared: dict | None = None,
    max_seq: int | None = None,
):
    """Returns (h, new_cache, aux). cache semantics depend on mode."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "shared_attn":
        # zamba2: weights shared across invocations; cache is per-invocation.
        return _apply_block(
            "attn", shared, cfg, h, mode=mode, cache=cache, index=index, max_seq=max_seq
        )

    window = cfg.sliding_window if kind == "attn_local" else None

    if kind in ("attn", "attn_local", "moe"):
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, cache = _decode_attn(p["attn"], cfg, x, cache, index, window=window)
        else:
            y, kv = _full_attention(
                p["attn"], cfg, x, window=window,
                want_cache=(mode == "prefill"), max_seq=max_seq,
            )
            cache = kv
        h = h + y
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y, aux = moe_mod.moe_ffn(p["moe"], cfg, x)
        else:
            y = mlp(p["mlp"], cfg, x)
        return h + y, cache, aux

    if kind == "mamba2":
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, cache = ssm_mod.mamba2_decode_step(p["ssm"], cfg, x, cache)
        elif mode == "prefill":
            # Final recurrent state falls out of the chunked scan — no O(S)
            # sequential replay (DESIGN.md perf note).
            y, cache = ssm_mod.mamba2_block(p["ssm"], cfg, x, return_state=True)
        else:
            y = ssm_mod.mamba2_block(p["ssm"], cfg, x)
        return h + y, cache, aux

    if kind in ("mlstm", "slstm"):
        mod_step = (
            xlstm_mod.mlstm_decode_step if kind == "mlstm" else xlstm_mod.slstm_decode_step
        )
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, cache = mod_step(p["cell"], cfg, x, cache)
        elif mode == "prefill":
            # One pass: scan the decode cell over the prompt, collecting both
            # the block outputs and the final state (identical math to decode).
            state0 = (
                xlstm_mod.init_mlstm_state(cfg, x.shape[0])
                if kind == "mlstm"
                else xlstm_mod.init_slstm_state(cfg, x.shape[0])
            )
            y, cache = _recurrent_prefill(
                lambda tok, st: mod_step(p["cell"], cfg, tok, st), state0, x
            )
        else:
            block = xlstm_mod.mlstm_block if kind == "mlstm" else xlstm_mod.slstm_block
            y = block(p["cell"], cfg, x)
        return h + y, cache, aux

    if kind == "cross_attn":
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            y = _cross_from_cache(p["attn"], cfg, x, cache)
        else:
            y, cache = _cross_attention(p["attn"], cfg, x, cross_src, want_cache=(mode == "prefill"))
        h = h + jnp.tanh(p["gate"]).astype(h.dtype) * y
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + mlp(p["mlp"], cfg, x), cache, aux

    if kind == "enc":
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        y, _ = _full_attention(p["attn"], cfg, x, causal=False, want_cache=False)
        h = h + y
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + mlp(p["mlp"], cfg, x), None, aux

    if kind == "dec":
        x = rms_norm(h, p["ln1"], cfg.norm_eps)
        if mode == "decode":
            y, self_cache = _decode_attn(p["attn"], cfg, x, cache["self"], index)
        else:
            y, self_cache = _full_attention(
                p["attn"], cfg, x, want_cache=(mode == "prefill"), max_seq=max_seq
            )
        h = h + y
        x = rms_norm(h, p["lnx"], cfg.norm_eps)
        if mode == "decode":
            y = _cross_from_cache(p["xattn"], cfg, x, cache["cross"])
            cross_cache = cache["cross"]
        else:
            y, cross_cache = _cross_attention(
                p["xattn"], cfg, x, cross_src, want_cache=(mode == "prefill")
            )
        h = h + y
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        new_cache = {"self": self_cache, "cross": cross_cache} if mode != "train" else None
        return h + mlp(p["mlp"], cfg, x), new_cache, aux

    raise ValueError(kind)


def _decode_attn(p, cfg, x, cache, index, *, window=None):
    """Decode-attention dispatch on the cache layout: a paged cache (the
    ``repro.serve`` engine's preallocated pool + page table) routes to
    ``paged_decode_attention``, the dense layout to ``decode_attention``.
    The layout is a property of the cache pytree, so the same jitted
    ``decode_step`` program serves both — treedef in, treedef out."""
    if isinstance(cache, dict) and "page_table" in cache:
        return attn_mod.paged_decode_attention(p, cfg, x, cache, index, window=window)
    return decode_attention(p, cfg, x, cache, index, window=window)


def _full_attention(p, cfg, x, *, causal=True, window=None, want_cache=False, max_seq=None):
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, x)
    if causal:  # RoPE only on causal (decoder) attention; whisper enc uses abs pos
        pos = jnp.arange(s)
        cos, sin = rope_angles(pos, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos[None, :, None, None, :], sin[None, :, None, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
    q = shard(q, "batch", "seq", "kv_heads", None, None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    mask = _causal_mask(s, s, window) if causal else None
    out = _sdpa(cfg, q, k, v, mask)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]
    cache = None
    if want_cache:
        if max_seq is not None and max_seq > s:
            pad = [(0, 0), (0, max_seq - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = {"k": k, "v": v}
    return out, cache


def _cross_attention(p, cfg, x, src, want_cache=False):
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, src)
    out = _sdpa(cfg, q, k, v, mask=None)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, ({"k": k, "v": v} if want_cache else None)


def _cross_from_cache(p, cfg, x, cache):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_kv_heads, cfg.q_groups, cfg.hd)
    out = _sdpa(cfg, q, cache["k"], cache["v"], mask=None)
    return out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]


def _recurrent_prefill(step_fn, state0, x):
    """Fold the prompt into a recurrent state, emitting per-token outputs."""

    def step(st, tok):
        y, st = step_fn(tok[:, None, :], st)
        return st, y[:, 0]

    state, ys = jax.lax.scan(step, state0, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1), state


# ---------------------------------------------------------------------------
# whisper encoder / frontends
# ---------------------------------------------------------------------------


def _encode(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: stubbed post-conv features (B, S_frames, frontend_dim)."""
    h = frames.astype(cfg.param_dtype) @ params["frontend_proj"]
    h = h + params["encoder"]["pos"][None]

    def body(h, blk):
        h, _, _ = _apply_block("enc", blk, cfg, h, mode="train")
        return h, ()

    h, _ = jax.lax.scan(body, h, params["encoder"]["stack"])
    return rms_norm(h, params["encoder"]["final_norm"], cfg.norm_eps)


def _cross_source(params, cfg: ArchConfig, aux_embeds):
    """Resolve the cross-attention source from stubbed frontend embeddings."""
    if aux_embeds is None:
        return None
    if cfg.encoder_layers:  # audio: run the encoder over the frames
        return _encode(params, cfg, aux_embeds)
    # vlm: project patch embeddings
    return aux_embeds.astype(cfg.param_dtype) @ params["frontend_proj"]


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _run_stack(
    params, cfg: ArchConfig, h, *, mode, caches=None, index=None, cross_src=None, max_seq=None
):
    """Scan the pattern groups. caches: list per slot of stacked pytrees."""
    shared = params.get("shared")
    n_slots = len(cfg.block_pattern)
    xs = (params["stacks"], caches if caches is not None else [None] * n_slots)

    # scan wants a single pytree of xs with uniform leading dim
    reps = cfg.pattern_repeats()

    def body(h, slot_inputs):
        blocks, slot_caches = slot_inputs
        aux_sum = jnp.zeros((), jnp.float32)
        new_caches = []
        for j, kind in enumerate(cfg.block_pattern):
            h, nc, aux = _apply_block(
                kind,
                blocks[j],
                cfg,
                h,
                mode=mode,
                cache=None if slot_caches[j] is None else slot_caches[j],
                index=index,
                cross_src=cross_src,
                shared=shared,
                max_seq=max_seq,
            )
            aux_sum = aux_sum + aux
            new_caches.append(nc)
        return h, (aux_sum, new_caches)

    if mode == "train" and cfg.remat == "full":
        # Gradient checkpointing on the layer-group body: backward recomputes
        # the group forward instead of saving O(S^2) attention intermediates
        # per layer — mandatory at production sequence lengths.
        body = jax.checkpoint(body)

    h, (aux_per_group, out_caches) = jax.lax.scan(body, h, xs)
    aux = jnp.sum(aux_per_group)
    if mode == "train":
        return h, aux, None
    return h, aux, out_caches


def forward(params, cfg: ArchConfig, tokens: jax.Array, aux_embeds=None):
    """Training forward: tokens (B, S) -> (logits (B,S,V), aux_loss)."""
    h = params["embed"][tokens]
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    h = shard(h, "batch", "seq", None)
    cross_src = _cross_source(params, cfg, aux_embeds)
    h, aux, _ = _run_stack(params, cfg, h, mode="train", cross_src=cross_src)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T)
    logits = h @ head
    logits = softcap(logits, cfg.final_softcap)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


def loss_fn(params, cfg: ArchConfig, batch) -> jax.Array:
    """batch: (tokens, targets) or (tokens, targets, aux_embeds)."""
    tokens, targets = batch[0], batch[1]
    aux_embeds = batch[2] if len(batch) > 2 else None
    logits, aux = forward(params, cfg, tokens, aux_embeds)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    xent = jnp.mean(logz - gold)
    return xent + MOE_AUX_COEF * aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_seq: int, page_size: int | None = None):
    """Abstract cache structure (stacked over pattern repeats) for decode.

    ``page_size`` switches self-attention caches to the paged layout
    (``attention.init_paged_kv_cache`` — the serving substrate); recurrent
    states (mamba2/mlstm/slstm) and the fixed-width cross caches are O(1) in
    sequence length and have nothing to page."""
    reps = cfg.pattern_repeats()

    def kv_cache():
        if page_size is not None:
            return attn_mod.init_paged_kv_cache(cfg, batch, max_seq, page_size)
        return attn_mod.init_kv_cache(cfg, batch, max_seq)

    def one(kind):
        if kind in ("attn", "attn_local", "moe", "shared_attn"):
            return kv_cache()
        if kind == "mamba2":
            return ssm_mod.init_mamba2_state(cfg, batch)
        if kind == "mlstm":
            return xlstm_mod.init_mlstm_state(cfg, batch)
        if kind == "slstm":
            return xlstm_mod.init_slstm_state(cfg, batch)
        if kind == "cross_attn":
            return {
                "k": jnp.zeros((batch, cfg.frontend_seq, cfg.n_kv_heads, cfg.hd), cfg.param_dtype),
                "v": jnp.zeros((batch, cfg.frontend_seq, cfg.n_kv_heads, cfg.hd), cfg.param_dtype),
            }
        if kind == "dec":
            return {
                "self": kv_cache(),
                "cross": {
                    "k": jnp.zeros(
                        (batch, cfg.frontend_seq, cfg.n_kv_heads, cfg.hd), cfg.param_dtype
                    ),
                    "v": jnp.zeros(
                        (batch, cfg.frontend_seq, cfg.n_kv_heads, cfg.hd), cfg.param_dtype
                    ),
                },
            }
        raise ValueError(kind)

    return [
        jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one(kind))
        for kind in cfg.block_pattern
    ]


def prefill(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,
    aux_embeds=None,
    max_seq=None,
    page_size: int | None = None,
):
    """Process the prompt, return (logits, caches).  Attention caches are
    padded to ``max_seq`` (defaults to the prompt length) so subsequent
    ``decode_step`` calls can append in place.

    ``page_size`` repacks the attention caches into the paged decode layout
    (``attention.pack_kv_to_pages``) before returning: prefill computes in
    the cheap contiguous layout, decode indexes through the page table — the
    prefill->decode hand-off of the serving engine."""
    h = params["embed"][tokens]
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    h = shard(h, "batch", "seq", None)
    cross_src = _cross_source(params, cfg, aux_embeds)
    h, _, caches = _run_stack(
        params, cfg, h, mode="prefill", cross_src=cross_src, max_seq=max_seq
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T)
    logits = softcap(h[:, -1:] @ head, cfg.final_softcap)
    if page_size is not None:
        caches = _caches_to_pages(cfg, caches, page_size)
    return logits, caches


def _caches_to_pages(cfg: ArchConfig, caches, page_size: int):
    """Repack every self-attention slot cache (stacked over pattern repeats)
    into the paged layout; recurrent and cross caches pass through."""

    def pack(cache):  # vmapped over the leading repeats axis
        return jax.vmap(lambda c: attn_mod.pack_kv_to_pages(c, page_size))(cache)

    out = []
    for kind, cache in zip(cfg.block_pattern, caches):
        if kind in ("attn", "attn_local", "moe", "shared_attn"):
            out.append(pack(cache))
        elif kind == "dec":
            out.append({"self": pack(cache["self"]), "cross": cache["cross"]})
        else:
            out.append(cache)
    return out


def decode_step(params, cfg: ArchConfig, token: jax.Array, caches, index: jax.Array):
    """token (B, 1) int32; index = number of tokens already in cache."""
    h = params["embed"][token]
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    h, _, caches = _run_stack(params, cfg, h, mode="decode", caches=caches, index=index)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T)
    logits = softcap(h @ head, cfg.final_softcap)
    return logits, caches
