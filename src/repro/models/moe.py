"""Mixture-of-Experts FFN: top-k router with capacity-based dense dispatch.

TPU adaptation: token->expert routing is expressed as one-hot dispatch/combine
einsums (GShard/Switch style) rather than host-side gathers — the dispatch
tensors become all-to-all-like reshards under GSPMD when experts are sharded
over the `model` mesh axis, and the expert GEMMs stay MXU-shaped.

Includes the auxiliary load-balance loss (Switch Transformer eq. 4) surfaced
to the trainer, and the optional *dense residual* branch of Arctic (a small
always-on MLP in parallel with the MoE output).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, uniform_init
from repro.models.mlp import init_mlp, mlp
from repro.models.sharding import shard

__all__ = ["init_moe", "moe_ffn"]


def init_moe(cfg: ArchConfig, key: jax.Array) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": uniform_init(ks[0], (d, e), jnp.float32),
        "w_gate": uniform_init(ks[1], (e, d, f), cfg.param_dtype),
        "w_up": uniform_init(ks[2], (e, d, f), cfg.param_dtype),
        "w_down": uniform_init(ks[3], (e, f, d), cfg.param_dtype),
    }
    if cfg.dense_residual:
        p["dense"] = init_mlp(cfg, ks[4], d_ff=cfg.d_ff, gated=True)
    return p


def moe_ffn(params: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss). x: (B, S, d)."""
    if cfg.moe_impl == "a2a":
        from repro.models.sharding import current_mesh

        mesh = current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            return _moe_ffn_a2a(params, cfg, x, mesh)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_tok = b * s
    xf = x.reshape(n_tok, d)

    gates = jax.nn.softmax(xf.astype(jnp.float32) @ params["router"], axis=-1)  # (T, E)
    top_w, top_idx = jax.lax.top_k(gates, k)  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # capacity per expert
    cap = int(max(1, round(cfg.capacity_factor * n_tok * k / e)))

    # Slot assignment without a (T, E, C) one-hot: a single (T, E) cumsum
    # gives each (token, expert) pair its position in the expert's buffer
    # (top-k experts are distinct per token, so the mask is 0/1).
    expert_mask = jnp.sum(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=1)  # (T, E)
    position = jnp.cumsum(expert_mask, axis=0) * expert_mask - 1.0  # (T, E)
    slot = jnp.take_along_axis(position, top_idx, axis=1).astype(jnp.int32)  # (T, k)
    keep = jnp.logical_and(slot >= 0, slot < cap)  # capacity drop
    slot_c = jnp.clip(slot, 0, cap - 1)

    # Scatter tokens into (E, C, d) expert buffers: k static scatter-adds.
    ex_in = jnp.zeros((e, cap, d), x.dtype)
    for kk in range(k):
        contrib = jnp.where(keep[:, kk : kk + 1], xf, 0).astype(x.dtype)
        ex_in = ex_in.at[top_idx[:, kk], slot_c[:, kk]].add(contrib)
    ex_in = shard(ex_in, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", ex_in, params["w_up"])
    g = jnp.einsum("ecd,edf->ecf", ex_in, params["w_gate"])
    h = h * jax.nn.silu(g)
    h = shard(h, "experts", None, "ffn")
    ex_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, d)

    # Combine: k gathers weighted by the renormalized router weights.
    out = jnp.zeros_like(xf)
    for kk in range(k):
        piece = ex_out[top_idx[:, kk], slot_c[:, kk]]  # (T, d)
        w = jnp.where(keep[:, kk], top_w[:, kk], 0.0)[:, None].astype(x.dtype)
        out = out + w * piece
    out = out.reshape(b, s, d)

    if "dense" in params:
        out = out + mlp(params["dense"], cfg, x)

    # Switch load-balance aux: E * sum_e (frac_tokens_e * mean_gate_e)
    frac = jnp.mean(expert_mask, axis=0)  # (E,)
    mean_gate = jnp.mean(gates, axis=0)
    aux = e * jnp.sum(frac * mean_gate)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map all-to-all dispatch (EXPERIMENTS.md section Perf, qwen3 iteration)
# ---------------------------------------------------------------------------


def _pack_by_dest(xf, dest, n_dest: int, cap: int, valid=None):
    """Pack rows of xf (T, d) into (n_dest, cap, d) buffers by dest (T,).

    Returns (buffers, slot (T,), kept (T,)) — the cumsum slotting trick;
    overflow rows beyond `cap` are dropped; rows with ``valid=False`` (e.g.
    padding arriving from the wire) neither occupy slots nor contribute.
    """
    t = xf.shape[0]
    onehot = jax.nn.one_hot(dest, n_dest, dtype=jnp.float32)  # (T, n_dest)
    if valid is not None:
        onehot = onehot * valid[:, None].astype(jnp.float32)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1.0
    slot = jnp.max(pos, axis=1).astype(jnp.int32)  # position within dest
    kept = jnp.logical_and(slot >= 0, slot < cap)
    slot_c = jnp.clip(slot, 0, cap - 1)
    buf = jnp.zeros((n_dest, cap, xf.shape[1]), xf.dtype)
    buf = buf.at[dest, slot_c].add(jnp.where(kept[:, None], xf, 0))
    return buf, slot_c, kept


def _moe_ffn_a2a(params: dict, cfg: ArchConfig, x: jax.Array, mesh):
    """Expert-parallel MoE with explicit all-to-all dispatch.

    Token layout: batch sharded over the batch axes, sequence over `model`
    (sequence-parallel residual stream), so every (data, model) shard owns a
    disjoint token slice.  Each shard routes its tokens, exchanges them with
    the expert owners via all-to-all over `model`, runs its local experts,
    and all-to-alls the results back — the canonical TPU MoE schedule.
    Collective volume: O(3 * T_local * k * d) per layer instead of the
    O(E * cap * d) full-buffer all-reduces of the GSPMD scatter path.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import batch_axes

    b_axes = batch_axes(mesh)
    n_model = mesh.shape["model"]
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    e_local = e // n_model
    bsz, s, _ = x.shape
    t_local = (bsz // _axsize(mesh, b_axes)) * (s // n_model)
    # per-destination-shard capacity (pair capacity) and local expert capacity
    cap_pair = int(max(8, round(cfg.capacity_factor * t_local * k / n_model)))
    cap_local = int(max(8, round(cfg.capacity_factor * t_local * k * 1.0 / e_local)))

    def body(xb, router, w_gate, w_up, w_down):
        # xb (B_loc, S_loc, d); expert weights are this shard's slice (E_loc,..)
        t = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(t, d)
        gates = jax.nn.softmax(xf.astype(jnp.float32) @ router, axis=-1)  # (t, E)
        top_w, top_idx = jax.lax.top_k(gates, k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

        # flatten the k assignments; destination shard owns expert block
        flat_idx = top_idx.reshape(t * k)
        flat_w = top_w.reshape(t * k)
        dest = flat_idx // e_local
        x_rep = jnp.repeat(xf, k, axis=0)  # (t*k, d)
        send, slot, kept = _pack_by_dest(x_rep, dest, n_model, cap_pair)
        # ship expert-local ids alongside, +1 so 0 marks wire padding
        meta = (flat_idx % e_local + 1).astype(xf.dtype)[:, None]
        send_meta, _, _ = _pack_by_dest(meta, dest, n_model, cap_pair)

        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0, tiled=True)
        recv_meta = jax.lax.all_to_all(
            send_meta, "model", split_axis=0, concat_axis=0, tiled=True
        )

        # local expert compute: scatter received rows into per-expert buffers
        rows = recv.reshape(n_model * cap_pair, d)
        meta_rows = recv_meta.reshape(n_model * cap_pair)
        wire_valid = meta_rows > 0.5
        eid = jnp.clip(meta_rows.astype(jnp.int32) - 1, 0, e_local - 1)
        ebuf, eslot, ekept = _pack_by_dest(rows, eid, e_local, cap_local, valid=wire_valid)
        h = jnp.einsum("ecd,edf->ecf", ebuf, w_up)
        g = jnp.einsum("ecd,edf->ecf", ebuf, w_gate)
        h = h * jax.nn.silu(g)
        eout = jnp.einsum("ecf,efd->ecd", h, w_down)  # (E_loc, cap_local, d)
        # un-scatter back to the received-row order
        back_rows = jnp.where(
            ekept[:, None], eout[eid, eslot], 0
        )  # (n_model*cap_pair, d)
        back = back_rows.reshape(n_model, cap_pair, d)
        ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0, tiled=True)

        # combine at the source: gather each assignment's row, weight, sum
        got = jnp.where(kept[:, None], ret[dest, slot], 0)  # (t*k, d)
        out = jnp.sum(
            (got * flat_w[:, None].astype(got.dtype)).reshape(t, k, d), axis=1
        )
        # load-balance aux (local estimate; averaged over shards by psum/size)
        frac = jnp.mean(
            jnp.sum(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=1), axis=0
        )
        mean_gate = jnp.mean(gates, axis=0)
        aux = e * jnp.sum(frac * mean_gate)
        aux = jax.lax.pmean(jax.lax.pmean(aux, "model"), b_axes)
        return out.reshape(xb.shape), aux

    bspec = b_axes if len(b_axes) > 1 else b_axes[0]
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, "model", None),  # x: batch over data(+pod), seq over model
            P(),  # router replicated
            P("model", None, None),  # experts over model
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(bspec, "model", None), P()),
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])

    if "dense" in params:
        out = out + mlp(params["dense"], cfg, x)
    return out, aux


def _axsize(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
