"""Launchers.  NOTE: repro.launch.dryrun must run as its own process
(python -m repro.launch.dryrun) — it forces the host-device count before jax
init.  Importing this package does NOT import dryrun for that reason."""
from repro.launch.mesh import batch_axes, fsdp_axes, make_production_mesh

__all__ = ["batch_axes", "fsdp_axes", "make_production_mesh"]
