"""Serving driver: paged-KV-cache decode, standalone or following a trainer.

Demo mode — decode from freshly initialized weights (engine smoke test)::

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 2 --prompt-len 16 --new-tokens 8 --temperature 0.8

Follow mode — the serve side of the train-to-serve loop.  Point it at the
``<ckpt>_ckpts`` directory of a running (or finished) ``launch.train
--compiled --ckpt ... --ckpt-every N`` process::

  PYTHONPATH=src python -m repro.launch.serve --follow /tmp/fl_ckpts

Follow mode reads ``spec.json`` from the checkpoint directory (written by
the trainer before round 0; ``--spec`` overrides), rebuilds the experiment
and the restore template from it, and serves synthetic prompt traffic while
watching the manifest: every newly committed boundary is restored
(fingerprint + treedef validated — ``repro.serve`` package docstring has
the full hand-off contract), scored on held-out loss by the promotion gate,
and hot-swapped into the engine iff it is no worse than what is being
served (``PromotionGate``).  Decode never stops for a swap and the decode
program never recompiles across swaps.  Serving geometry and gate policy
come from the spec's ``serve`` section (``repro.api.ServeSpec``).

Exits printing the promotion log and a machine-readable summary line::

  serve summary: promotions=2 rollbacks=1 tokens=1920 tokens_per_sec=412.3 ...

PRNG discipline (the old driver reused ONE key for params, prompts, and
sampling, and always took the first post-prefill token greedily): every
consumer gets its own split — prompt synthesis draws from a dedicated
traffic stream, the engine's sampling stream is seeded separately, and the
first generated token goes through the same temperature-respecting sampler
as every later one (inside the jitted prefill).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer


def _demo(args) -> None:
    """Standalone decode from fresh weights — no checkpoint directory."""
    from repro.serve import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    k_params, k_prompts, k_sample = jax.random.split(key, 3)
    params = transformer.init_params(cfg, k_params)

    engine = ServeEngine(
        cfg,
        params,
        batch=args.batch,
        max_seq=args.prompt_len + args.new_tokens,
        page_size=args.page_size,
        temperature=args.temperature,
        seed=int(jax.random.randint(k_sample, (), 0, 2**31 - 1)),
    )
    prompts = jax.random.randint(
        k_prompts, (args.batch, args.prompt_len), 0, cfg.vocab
    )

    t0 = time.time()
    engine.start(prompts)
    print(f"prefill {args.batch}x{args.prompt_len} in {time.time() - t0:.2f}s")
    engine.step(args.new_tokens - 1)
    print(
        f"decoded {args.new_tokens - 1} steps in {engine.decode_seconds:.2f}s "
        f"({engine.tokens_per_sec():.1f} tok/s, "
        f"{engine.decode_cache_entries()} decode compile)"
    )
    print("generated ids:", engine.generated().tolist())


def _load_followed_spec(ckpt_dir: str, spec_path: str, timeout: float):
    """The spec of the run being followed: ``--spec`` wins, else wait for
    the trainer's ``spec.json`` to appear in the checkpoint directory."""
    from repro.api import ExperimentSpec

    if spec_path:
        return ExperimentSpec.load(spec_path)
    path = os.path.join(ckpt_dir, "spec.json")
    deadline = time.monotonic() + timeout
    while not os.path.exists(path):
        if time.monotonic() >= deadline:
            raise FileNotFoundError(
                f"no {path} after {timeout:.0f}s — is launch.train running "
                "with --compiled --ckpt --ckpt-every on this directory? "
                "(or pass --spec explicitly)"
            )
        time.sleep(0.1)
    return ExperimentSpec.load(path)


def _follow(args) -> None:
    """Follow a training checkpoint directory: the serve side of the loop."""
    from repro import api
    from repro.checkpoint import CheckpointManager, config_fingerprint
    from repro.serve import (
        CheckpointWatcher,
        PromotionGate,
        ServeEngine,
        ServeSession,
        heldout_batches,
    )

    spec = _load_followed_spec(args.follow, args.spec, args.timeout)
    srv = spec.serve
    built = api.build(spec)
    cfg = built.arch_config
    if cfg is None:
        raise SystemExit(
            "--follow serves zoo runs (TaskSpec.kind='zoo'); the followed "
            f"spec has kind={spec.task.kind!r}"
        )
    template = api.restore_template(spec, built=built)
    manager = CheckpointManager(
        args.follow, fingerprint=config_fingerprint(spec.to_dict())
    )

    # Round-0 weights: the engine starts serving the untrained model and the
    # gate's bar is ITS held-out loss — the first trained boundary promotes
    # iff training helped.
    engine = ServeEngine(
        cfg,
        template.params,
        batch=srv.batch,
        max_seq=srv.max_seq,
        page_size=srv.page_size,
        temperature=args.temperature if args.temperature is not None else srv.temperature,
        seed=spec.execution.seed + 1,
    )
    gate = PromotionGate(
        cfg,
        heldout_batches(
            built.dataset,
            n_batches=srv.eval_batches,
            batch_size=spec.federation.batch_size,
            seed=spec.execution.seed,
        ),
        tolerance=srv.tolerance,
    )
    watcher = CheckpointWatcher(manager, template)

    traffic_key = [jax.random.fold_in(jax.random.PRNGKey(spec.execution.seed), 11)]

    def prompt_fn():
        traffic_key[0], sub = jax.random.split(traffic_key[0])
        return jax.random.randint(sub, (srv.batch, srv.prompt_len), 0, cfg.vocab)

    def on_decision(candidate, promoted):
        rec = gate.log.records[-1]
        print(
            f"boundary step {candidate.step}: "
            f"{'PROMOTE' if promoted else 'ROLLBACK'} ({rec.reason}); "
            f"serving at {engine.tokens_per_sec():.1f} tok/s",
            flush=True,
        )

    print(
        f"following {args.follow} (arch={cfg.name}, horizon="
        f"{spec.federation.rounds} rounds); gate bar (round-0 init) = "
        f"{gate.prime(engine.params):.4f}",
        flush=True,
    )
    session = ServeSession(
        engine,
        watcher,
        gate,
        prompt_fn=prompt_fn,
        decode_steps_per_poll=srv.decode_steps_per_poll,
        final_step=spec.federation.rounds,
        on_decision=on_decision,
    )
    summary = session.run(timeout=args.timeout, poll_timeout=args.poll)
    assert engine.decode_cache_entries() == 1, (
        f"decode recompiled under swaps: {engine.decode_cache_entries()} "
        "jit cache entries (compile-once contract)"
    )
    print(gate.log.render())
    print(summary.render(), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Paged-KV-cache serving: standalone demo, or --follow a "
        "training checkpoint directory with eval-gated hot swaps"
    )
    ap.add_argument(
        "--follow", default="", metavar="CKPT_DIR",
        help="follow this CheckpointManager directory (the <ckpt>_ckpts dir "
        "of launch.train --compiled --ckpt-every): hot-swap each committed "
        "boundary that clears the promotion gate",
    )
    ap.add_argument(
        "--spec", default="",
        help="ExperimentSpec JSON of the followed run (default: wait for "
        "CKPT_DIR/spec.json, which launch.train writes)",
    )
    ap.add_argument(
        "--timeout", type=float, default=120.0,
        help="follow mode: overall serving wall-clock budget (and the wait "
        "budget for spec.json to appear)",
    )
    ap.add_argument(
        "--poll", type=float, default=0.2,
        help="follow mode: manifest poll bound between decode chunks (s)",
    )
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument(
        "--temperature", type=float, default=None,
        help="sampling temperature (demo default 0.0; follow mode defaults "
        "to the spec's serve.temperature)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.follow:
        _follow(args)
    else:
        if args.temperature is None:
            args.temperature = 0.0
        _demo(args)


if __name__ == "__main__":
    main()
