"""Batched serving driver: prefill a prompt batch, decode new tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 2 --prompt-len 16 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)

    max_seq = args.prompt_len + args.new_tokens
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    aux = None
    if cfg.frontend:
        fd = cfg.frontend_dim or cfg.d_model
        aux = jax.random.normal(key, (args.batch, cfg.frontend_seq, fd), jnp.float32)

    prefill = jax.jit(lambda p, t, a: transformer.prefill(p, cfg, t, a, max_seq=max_seq))
    decode = jax.jit(lambda p, tok, c, i: transformer.decode_step(p, cfg, tok, c, i))

    t0 = time.time()
    logits, caches = prefill(params, prompts, aux)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, caches = transformer_decode(decode, params, tok, caches, args.prompt_len + i)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, 0] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new_tokens - 1} steps in {dt:.2f}s "
          f"({(args.new_tokens - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("generated ids:", toks.tolist())


def transformer_decode(decode, params, tok, caches, index):
    return decode(params, tok, caches, jnp.asarray(index, jnp.int32))


if __name__ == "__main__":
    main()
