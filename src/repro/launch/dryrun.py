import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production meshes and record memory / cost /
collective analysis.

MUST be executed as its own process (``python -m repro.launch.dryrun``) —
the XLA_FLAGS assignment above precedes every jax import, including the
``from repro...`` ones, because jax locks the device count at first init.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out results/dryrun]   # subprocess per combo

Unlike the training front doors (``repro.api.run`` / ``repro.launch.train``,
which consume a declarative ``repro.api.ExperimentSpec``), the dry-run
deliberately sits below the spec layer: it sweeps raw (arch, shape, mesh)
combos with abstract inputs and never builds a dataset or sampler.
"""
import argparse
import functools
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import active_params, model_flops
from repro.configs import INPUT_SHAPES, get_config, input_specs, list_archs, step_kind
from repro.fed.round import RoundSpec, build_round_step
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.sharding import (
    activation_rules,
    cache_shardings,
    param_shardings,
    param_specs,
)
from repro.models import sharding as msharding
from repro.models import transformer

COHORT_PARALLEL = 16  # clients per round, client_parallel (= data-axis size)
COHORT_SEQUENTIAL = 4  # scan length, cohort_sequential
LOCAL_STEPS = 2


def _long_cfg(arch: str):
    """Arch config used for the long_500k shape (sliding-window variant for
    the dense long-context entry)."""
    if arch == "llama3.2-1b":
        from repro.configs.llama3_2_1b import SW_CONFIG

        return SW_CONFIG
    return get_config(arch)


def _cfg_for(arch: str, shape_name: str):
    return _long_cfg(arch) if shape_name == "long_500k" else get_config(arch)


def _abstract_params(cfg):
    return jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))


def _train_setup(cfg, shape, mesh):
    """Lower the federated round step (the paper's technique IS the train step)."""
    cohort = COHORT_PARALLEL if cfg.round_mode == "client_parallel" else COHORT_SEQUENTIAL
    if cfg.round_mode == "client_parallel" and "pod" in mesh.axis_names:
        cohort *= mesh.shape["pod"]
    b_local = shape.global_batch // (cohort * LOCAL_STEPS)
    assert b_local >= 1, (cfg.name, shape.name, cohort)
    spec = RoundSpec(cohort=cohort, local_steps=LOCAL_STEPS, local_lr=0.02)

    params = _abstract_params(cfg)
    fsdp = cfg.round_mode == "cohort_sequential"
    p_shard = param_shardings(params, mesh, fsdp=fsdp)

    if os.environ.get("REPRO_NO_ACC_CONSTRAINT"):
        constrain = None  # reproduces the pre-fix baseline (qwen3 iter 1)
    else:
        constrain = lambda tree: jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, p_shard
        )
    round_step = build_round_step(cfg, spec, constrain=constrain)
    b_axes = batch_axes(mesh)
    tok = jax.ShapeDtypeStruct((cohort, LOCAL_STEPS, b_local, shape.seq_len), jnp.int32)
    w = jax.ShapeDtypeStruct((cohort,), jnp.float32)
    if cfg.round_mode == "client_parallel":
        data_in = NamedSharding(mesh, P(b_axes))  # clients over batch axes
    else:
        data_in = NamedSharding(mesh, P(None, None, b_axes))  # batch-per-client
    args = [params, tok, tok, w]
    in_sh = [p_shard, data_in, data_in, NamedSharding(mesh, P())]
    if cfg.frontend:
        fd = cfg.frontend_dim or cfg.d_model
        aux = jax.ShapeDtypeStruct(
            (cohort, LOCAL_STEPS, b_local, cfg.frontend_seq, fd), jnp.float32
        )
        if cfg.round_mode == "client_parallel":
            aux_sh = NamedSharding(mesh, P(b_axes))
        else:
            aux_sh = NamedSharding(mesh, P(None, None, b_axes))
        args.append(aux)
        in_sh.append(aux_sh)
    out_sh = (p_shard, NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    fn = jax.jit(
        round_step, in_shardings=tuple(in_sh), out_shardings=out_sh,
        donate_argnums=(0,),
    )
    tokens_processed = shape.global_batch * shape.seq_len
    return fn, args, tokens_processed, "train"


def _prefill_setup(cfg, shape, mesh):
    params = _abstract_params(cfg)
    fsdp = cfg.round_mode == "cohort_sequential"
    p_shard = param_shardings(params, mesh, fsdp=fsdp)
    b_axes = batch_axes(mesh)
    specs = input_specs(cfg, shape)
    args = [params, specs["tokens"]]
    in_sh = [p_shard, NamedSharding(mesh, P(b_axes))]
    kwargs = {}
    if "aux_embeds" in specs:
        args.append(specs["aux_embeds"])
        in_sh.append(NamedSharding(mesh, P(b_axes)))

    def fn(params, tokens, aux=None):
        return transformer.prefill(params, cfg, tokens, aux)

    jfn = jax.jit(fn, in_shardings=tuple(in_sh))
    tokens_processed = shape.global_batch * shape.seq_len
    return jfn, args, tokens_processed, "prefill"


def _decode_setup(cfg, shape, mesh):
    params = _abstract_params(cfg)
    fsdp = cfg.round_mode == "cohort_sequential"
    p_shard = param_shardings(params, mesh, fsdp=fsdp)
    b_axes = batch_axes(mesh)
    specs = input_specs(cfg, shape)
    caches = specs["caches"]
    c_shard = cache_shardings(caches, mesh, shape.seq_len, shape.global_batch)
    b_size = 1
    for a in b_axes:
        b_size *= mesh.shape[a]
    tok_sh = (
        NamedSharding(mesh, P(b_axes))
        if shape.global_batch % b_size == 0 and shape.global_batch > 1
        else NamedSharding(mesh, P())
    )

    def fn(params, token, caches, index):
        return transformer.decode_step(params, cfg, token, caches, index)

    jfn = jax.jit(
        fn,
        in_shardings=(p_shard, tok_sh, c_shard, NamedSharding(mesh, P())),
    )
    args = [params, specs["token"], caches, specs["index"]]
    tokens_processed = shape.global_batch  # one new token per sequence
    return jfn, args, tokens_processed, "decode"


def run_one(arch: str, shape_name: str, multi_pod: bool, opts: tuple = ()) -> dict:
    """opts: perf-variant switches recorded in EXPERIMENTS.md section Perf:
      seq_parallel   — shard the residual-stream sequence dim over `model`
                       (universal balance for non-divisible head counts)
      remat_none     — disable layer-group gradient checkpointing
      mlstm_chunked  — chunkwise-parallel mLSTM cell (see models/xlstm.py)
    """
    import dataclasses as _dc

    shape = INPUT_SHAPES[shape_name]
    cfg = _cfg_for(arch, shape_name)
    if "remat_none" in opts:
        cfg = _dc.replace(cfg, remat="none")
    if "attn_chunked" in opts:
        cfg = _dc.replace(cfg, attn_impl="chunked")
    if "moe_a2a" in opts:
        cfg = _dc.replace(cfg, moe_impl="a2a")
    if "mlstm_chunked" in opts:
        cfg = _dc.replace(cfg, mlstm_impl="chunked")
    for o in opts:
        if o.startswith("mlstm_chunk_"):
            cfg = _dc.replace(cfg, mlstm_impl="chunked", mlstm_chunk=int(o.rsplit("_", 1)[1]))
        if o.startswith("slstm_seg_"):
            cfg = _dc.replace(cfg, slstm_segment=int(o.rsplit("_", 1)[1]))
    kind = step_kind(cfg, shape)
    if kind is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "status": "skip",
                "reason": "full-attention arch skips long_500k (DESIGN.md section 4)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    setup = {"train": _train_setup, "prefill": _prefill_setup, "decode": _decode_setup}[kind]
    long_ctx = shape_name == "long_500k"
    cp = kind == "train" and cfg.round_mode == "client_parallel"
    rules = activation_rules(mesh, long_context=long_ctx, client_parallel=cp)
    if "seq_parallel" in opts:
        rules["seq"] = ("model",)
    with msharding.use_rules(mesh, rules):
        fn, args, tokens_processed, kind = setup(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    walk = analyze_hlo(hlo)  # trip-count-aware (cost_analysis counts scan bodies once)

    n_chips = mesh.devices.size
    params_abs = _abstract_params(cfg)
    n_active = active_params(cfg, params_abs)
    mf = model_flops(n_active, tokens_processed, kind)

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "opts": list(opts),
        "status": "ok",
        "kind": kind,
        "n_chips": n_chips,
        "round_mode": cfg.round_mode,
        "flops": walk["flops"],
        "bytes_accessed": walk["bytes"],
        "collective_bytes": walk["collective_bytes"],
        "collectives": walk["collectives"],
        "raw_cost_analysis": {
            "flops_scan_body_once": float(cost.get("flops", 0.0)),
            "bytes_scan_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_size_bytes": int(mem.argument_size_in_bytes),
            "output_size_bytes": int(mem.output_size_in_bytes),
            "temp_size_bytes": int(mem.temp_size_in_bytes),
            "generated_code_size_bytes": int(mem.generated_code_size_in_bytes),
        },
        "active_params": float(n_active),
        "tokens_processed": float(tokens_processed),
        "model_flops": float(mf),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--opt", default="", help="comma-separated perf variants")
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        combos = []
        for arch in list_archs():
            for shape_name in INPUT_SHAPES:
                for mp in (False, True):
                    combos.append((arch, shape_name, mp))
        for arch, shape_name, mp in combos:
            tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print("cached", tag)
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name,
            ] + (["--multi-pod"] if mp else [])
            print(">>>", tag, flush=True)
            try:
                proc = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=args.timeout,
                    env={**os.environ, "PYTHONPATH": "src"},
                )
                if proc.returncode == 0:
                    # last line of stdout is the JSON result
                    result = json.loads(proc.stdout.strip().splitlines()[-1])
                else:
                    result = {
                        "arch": arch, "shape": shape_name, "multi_pod": mp,
                        "status": "error", "stderr": proc.stderr[-4000:],
                    }
            except subprocess.TimeoutExpired:
                result = {"arch": arch, "shape": shape_name, "multi_pod": mp,
                          "status": "timeout"}
            with open(path, "w") as f:
                json.dump(result, f, indent=1)
            print(
                "   ", result["status"],
                f"compile={result.get('compile_s', '-')}s" if result["status"] == "ok" else "",
                flush=True,
            )
        return

    opts = tuple(o for o in args.opt.split(",") if o)
    result = run_one(args.arch, INPUT_SHAPES[args.shape].name, args.multi_pod, opts)
    print(json.dumps(result))


if __name__ == "__main__":
    main()
