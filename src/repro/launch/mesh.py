"""Production meshes (TPU v5e pods) + the sampler shard layout (``ShardSpec``).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run entrypoint sets XLA_FLAGS *before* any jax import.
``ShardSpec`` is the one exception to the functions-only rule: it is a
frozen, hashable *description* of a layout (mesh shape + axis names + which
axis carries the client dimension) — building it touches no device state
either; the mesh is materialized lazily by ``ShardSpec.mesh()``.
"""
from __future__ import annotations

import dataclasses

import jax

__all__ = [
    "ShardSpec",
    "make_production_mesh",
    "make_host_mesh",
    "fsdp_axes",
    "batch_axes",
]


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Declarative layout of a sampler's (N,) client axis over a mesh.

    The sampler stack is configured with a ``ShardSpec`` (not a live
    ``Mesh``) so the frozen ``Sampler`` dataclasses stay hashable and
    JSON-describable: ``axes`` is the full mesh shape as
    ``((name, size), ...)`` pairs and ``axis`` names the mesh axis the
    (N,) client dimension is split over (every other axis replicates it).
    Two processes agreeing on a ``ShardSpec`` agree on the layout — which
    is why checkpoint manifests record ``to_manifest()`` and why restoring
    onto a *different* mesh shape is legal: the arrays round-trip through
    host numpy and are re-laid-out by the restoring process's own spec.
    """

    axes: tuple = (("data", 1),)  # ((axis_name, size), ...) — the mesh shape
    axis: str = "data"  # which axis carries the (N,) client dimension

    def __post_init__(self):
        object.__setattr__(
            self, "axes", tuple((str(n), int(s)) for n, s in self.axes)
        )
        names = [n for n, _ in self.axes]
        if self.axis not in names:
            raise ValueError(
                f"ShardSpec.axis {self.axis!r} is not a mesh axis; have {names}"
            )

    @classmethod
    def from_mesh(cls, mesh, axis: str = "data") -> "ShardSpec":
        return cls(
            axes=tuple(zip(mesh.axis_names, mesh.devices.shape)), axis=axis
        )

    @property
    def num_shards(self) -> int:
        return dict(self.axes)[self.axis]

    def mesh(self):
        """Materialize the described mesh over this process's devices."""
        return jax.make_mesh(
            tuple(s for _, s in self.axes), tuple(n for n, _ in self.axes)
        )

    def named_sharding(self, mesh=None):
        """NamedSharding splitting a leading (N,) axis over ``self.axis``."""
        from jax.sharding import NamedSharding, PartitionSpec

        return NamedSharding(mesh or self.mesh(), PartitionSpec(self.axis))

    def to_manifest(self) -> dict:
        """JSON-ready record for checkpoint manifests (provenance, not a
        restore constraint — see class docstring)."""
        return {"axes": [[n, s] for n, s in self.axes], "axis": self.axis}

    @classmethod
    def from_manifest(cls, data: dict) -> "ShardSpec":
        return cls(
            axes=tuple((n, s) for n, s in data["axes"]), axis=data["axis"]
        )


def _override_mesh():
    """REPRO_MESH_SHAPE env override, e.g. "4,4" or "2,4,4" (CI / host runs)."""
    import os

    override = os.environ.get("REPRO_MESH_SHAPE")
    if not override:
        return None
    shape = tuple(int(x) for x in override.split(","))
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    mesh = _override_mesh()
    if mesh is not None:
        return mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """(data, model) mesh over whatever devices THIS host exposes.

    REPRO_MESH_SHAPE overrides (same contract as ``make_production_mesh``);
    otherwise the model axis takes the largest of (16, 8, 4, 2, 1) dividing
    the device count.  One CPU device yields the degenerate (1, 1) mesh, so
    the mesh-parallel code path is exercised everywhere the tests run."""
    mesh = _override_mesh()
    if mesh is not None:
        return mesh
    n = len(jax.devices())
    model = next(cand for cand in (16, 8, 4, 2, 1) if n % cand == 0)
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes carrying the batch/client dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh) -> tuple:
    """Mesh axes over which fully-sharded parameters are scattered."""
    return batch_axes(mesh)
