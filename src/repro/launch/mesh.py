"""Production meshes (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run entrypoint sets XLA_FLAGS *before* any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "fsdp_axes", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    import os

    override = os.environ.get("REPRO_MESH_SHAPE")  # e.g. "4,4" or "2,4,4" (CI)
    if override:
        shape = tuple(int(x) for x in override.split(","))
        axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes carrying the batch/client dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh) -> tuple:
    """Mesh axes over which fully-sharded parameters are scattered."""
    return batch_axes(mesh)
