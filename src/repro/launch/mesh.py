"""Production meshes (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run entrypoint sets XLA_FLAGS *before* any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "fsdp_axes", "batch_axes"]


def _override_mesh():
    """REPRO_MESH_SHAPE env override, e.g. "4,4" or "2,4,4" (CI / host runs)."""
    import os

    override = os.environ.get("REPRO_MESH_SHAPE")
    if not override:
        return None
    shape = tuple(int(x) for x in override.split(","))
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    mesh = _override_mesh()
    if mesh is not None:
        return mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """(data, model) mesh over whatever devices THIS host exposes.

    REPRO_MESH_SHAPE overrides (same contract as ``make_production_mesh``);
    otherwise the model axis takes the largest of (16, 8, 4, 2, 1) dividing
    the device count.  One CPU device yields the degenerate (1, 1) mesh, so
    the mesh-parallel code path is exercised everywhere the tests run."""
    mesh = _override_mesh()
    if mesh is not None:
        return mesh
    n = len(jax.devices())
    model = next(cand for cand in (16, 8, 4, 2, 1) if n % cand == 0)
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes carrying the batch/client dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh) -> tuple:
    """Mesh axes over which fully-sharded parameters are scattered."""
    return batch_axes(mesh)
