"""End-to-end federated training driver: any zoo architecture x any sampler.

The canonical run description is ``repro.api.ExperimentSpec`` — the CLI
flags below are a thin shim that is parsed INTO a spec
(``build_spec_from_args``), and the spec is what actually runs:

  # flags -> spec -> run
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --rounds 8 --clients 32 --budget 6 --sampler kvib --seq 64 --ckpt /tmp/fl

  # print the spec a flag set denotes (no training), then run it verbatim
  PYTHONPATH=src python -m repro.launch.train [flags...] --dump-spec > exp.json
  PYTHONPATH=src python -m repro.launch.train --spec exp.json

The two invocations are equivalent by construction: ``--spec`` consumes
exactly what ``--dump-spec`` emits and reproduces the flag-driven run's
final parameters bit-for-bit (tests/test_launchers.py).  The checkpoint
manifest's ``config_fingerprint`` derives from ``spec.to_dict()`` — ANY
spec field change refuses to resume an old run's checkpoints.

The driver is the deployable realization of Algorithm 1, in two modes:

* default (host loop): per-round Python dispatch —
    host: sampler state, ISP draw, cohort selection/padding via the shared
          ``repro.fed.cohort`` contract (probabilities solved ONCE per round,
          unbiased |S|/C overflow rescaling, inert zero padding)
    device: the jitted federated round step (local SGD + cohort-width
            weighted aggregation + feedback norms in one program)
* ``--compiled``: the run executes as jitted ``lax.scan`` *segments* over
  rounds (``fed.round.build_fed_scan_segment`` driven by
  ``fed.state.run_segmented``) on the host mesh from ``repro.launch.mesh`` —
  draw, selection, device-side batch gather, sharded round step, and sampler
  update all inside the trace; both modes consume the identical key stream,
  so they train on the same draws and batches.  ``--ckpt-every N`` cuts the
  horizon into N-round segments (bitwise-neutral) and, with ``--ckpt DIR``,
  publishes the full ``TrainState`` — params, sampler's learned state, metric
  buffers, round index, RNG key — through a ``CheckpointManager`` at every
  boundary; ``--resume`` restarts a SIGKILL'd run from the manifest and
  reproduces the uninterrupted run's results exactly
  (tests/test_launchers.py).  ``--resume`` without the compiled path is an
  error: host-loop checkpoints hold params+sampler only (no RNG key, no
  round index) and cannot be resumed.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    CompressionSpec,
    ExecutionSpec,
    ExperimentSpec,
    FaultSpec,
    FederationSpec,
    SamplerSpec,
    TaskSpec,
    build,
)
from repro.checkpoint import CheckpointManager, config_fingerprint, save_checkpoint
from repro.core import estimator
from repro.core.samplers import sampler_names
from repro.fed import cohort as fed_cohort
from repro.fed.round import build_fed_scan_segment, build_round_step
from repro.fed.state import run_segmented
from repro.launch.mesh import make_host_mesh
from repro.models import transformer


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Federated training of a zoo arch; flags are a shim over "
        "repro.api.ExperimentSpec (--dump-spec shows the spec they denote)"
    )
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--sampler", default="kvib", choices=sampler_names())
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--budget", type=int, default=6)
    ap.add_argument("--cohort", type=int, default=8, help="padded cohort buffer C")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument(
        "--ckpt-every", type=int, default=0,
        help="checkpoint every N rounds; with --compiled this is the scan "
        "segment length (bitwise-neutral) and checkpoints go to the "
        "<ckpt>_ckpts/ CheckpointManager directory.  WITHOUT --compiled the "
        "host loop saves params+sampler snapshots only — no RNG key or round "
        "index — which are NOT resumable",
    )
    ap.add_argument(
        "--compiled", action="store_true",
        help="run the rounds as jitted lax.scan segments on the host mesh "
        "(fed.round.build_fed_scan_segment); default is the per-round host loop",
    )
    ap.add_argument(
        "--resume", action="store_true",
        help="with --compiled --ckpt --ckpt-every: resume from the newest "
        "committed step in <ckpt>_ckpts/manifest.json (fresh start if none). "
        "Errors without the compiled path: host-loop checkpoints are not "
        "resumable",
    )
    ap.add_argument(
        "--shard-sampler", default="", metavar="AXIS",
        help="shard every sampler (N,)-axis tensor over this mesh axis "
        "(e.g. 'data') — the million-client switch: the budget solve, draw, "
        "and feedback update run shard-local (ExecutionSpec.sampler_axis)",
    )
    ap.add_argument(
        "--faults", default="", metavar="JSON",
        help="deployment-realism fault layer as a FaultSpec JSON object, "
        "e.g. '{\"availability\": \"markov\", \"availability_kwargs\": "
        "{\"p_on\": 0.7, \"p_off\": 0.2}, \"deadline\": 1.0}' — availability "
        "processes, deadline stragglers (unbiased reweighting), and "
        "buffered-async aggregation.  Requires --compiled (the fault state "
        "lives in the scan carry)",
    )
    ap.add_argument(
        "--delta-dtype", default="", choices=["", "int8", "fp8"],
        help="quantize client deltas to this width inside the traced round "
        "(CompressionSpec.delta_dtype): the (C, D) stacked buffer lives in "
        "HBM at quantized width with per-(slot, block) fp32 scales and a "
        "server-side error-feedback residual in the carry.  Requires "
        "--compiled (the residual lives in the scan carry)",
    )
    ap.add_argument(
        "--no-error-feedback", action="store_true",
        help="with --delta-dtype: drop the error-feedback residual "
        "(ablation — quantization error then accumulates round over round)",
    )
    ap.add_argument(
        "--spec", default="",
        help="load the experiment from an ExperimentSpec JSON file (as "
        "emitted by --dump-spec); the experiment flags above are ignored",
    )
    ap.add_argument(
        "--dump-spec", action="store_true",
        help="print the ExperimentSpec JSON these flags denote and exit "
        "without training",
    )
    ap.add_argument(
        "--lint", action="store_true",
        help="statically lint the spec before training "
        "(repro.analysis.lint.run_suite: sampler scan-safety, round-body "
        "dtype hygiene, cohort-width) and abort with exit code 1 on any "
        "finding — no training happens on a spec that fails its contracts",
    )
    return ap


def build_spec_from_args(args) -> ExperimentSpec:
    """The flags->spec projection: the ONE place CLI flags acquire meaning.

    ``--spec``/``--dump-spec``/``--ckpt``/``--resume`` are not part of the
    experiment (they say where to run / persist it, not what it is) and do
    not appear in the spec."""
    return ExperimentSpec(
        task=TaskSpec(
            kind="zoo",
            name=args.arch,
            reduced=args.reduced,
            dataset="synthetic_tokens",
            dataset_kwargs={"n_clients": args.clients, "seq_len": args.seq},
        ),
        sampler=SamplerSpec(
            name=args.sampler,
            kwargs=(
                {"horizon": args.rounds} if args.sampler in ("kvib", "vrb") else {}
            ),
        ),
        federation=FederationSpec(
            rounds=args.rounds,
            budget=args.budget,
            cohort=args.cohort,
            local_steps=args.local_steps,
            batch_size=args.local_batch,
            local_lr=args.local_lr,
        ),
        execution=ExecutionSpec(
            seed=args.seed,
            compiled=args.compiled,
            ckpt_every=args.ckpt_every,
            sampler_axis=args.shard_sampler or None,
        ),
        fault=(
            FaultSpec(**json.loads(args.faults)) if args.faults else FaultSpec()
        ),
        compression=CompressionSpec(
            delta_dtype=args.delta_dtype or None,
            error_feedback=not args.no_error_feedback,
        ),
    )


def run_spec(spec: ExperimentSpec, *, ckpt: str = "", resume: bool = False) -> None:
    """Execute a zoo ExperimentSpec with launcher ergonomics (per-round
    prints, checkpoint publishing, kill/resume hooks).  The construction —
    arch config, dataset, sampler, RoundSpec, key stream — comes from
    ``repro.api.build``, so this trains the identical run ``repro.api.run``
    would."""
    built = build(spec)
    cfg, ds, sampler = built.arch_config, built.dataset, built.sampler
    rspec = built.round_spec
    fed, ex = built.spec.federation, spec.execution
    rounds, ckpt_every = fed.rounds, ex.ckpt_every
    lam = np.asarray(ds.lam)

    key = jax.random.PRNGKey(ex.seed)
    params = transformer.init_params(cfg, key)
    n_params = transformer.param_count(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M clients={ds.n_clients} "
          f"K={fed.budget} cohort={rspec.cohort} sampler={spec.sampler.name}")

    s_state = sampler.init()

    if ex.compiled:
        mesh = make_host_mesh()
        print(f"compiled scan on mesh {dict(mesh.shape)} ({len(mesh.devices.flat)} devices)")
        segment, make_state = build_fed_scan_segment(cfg, rspec, sampler, ds, mesh=mesh)
        # Identical key stream to the host loop below: per round
        # (key, k_draw, k_data) chained splits, derived in-trace segment by
        # segment from the TrainState's chain key.
        state = make_state(params, s_state, key, rounds)

        manager = None
        if resume and not (ckpt and ckpt_every):
            print("warning: --resume needs --ckpt AND --ckpt-every; starting fresh")
        if ckpt and ckpt_every:
            # The spec IS the run configuration: its canonical serialization
            # is what the manifest fingerprints, so resuming under ANY
            # changed spec field raises instead of silently mixing runs.
            fingerprint = config_fingerprint(spec.to_dict())
            manager = CheckpointManager(f"{ckpt}_ckpts", fingerprint=fingerprint)
            # Drop the spec next to the manifest BEFORE training: a serving
            # process following this directory (repro.launch.serve --follow)
            # reconstructs the full run configuration — and the matching
            # fingerprint — from this file alone.
            os.makedirs(manager.directory, exist_ok=True)
            spec.save(os.path.join(manager.directory, "spec.json"))
            if resume:
                state, start = manager.restore_or_init(state)
                if start:
                    print(f"resumed from checkpoint step {start} "
                          f"({rounds - start} rounds remaining)")

        # Test hook: self-SIGKILL after N published segments — how the
        # kill/resume integration test simulates a preemption that strikes
        # between segment boundaries.
        kill_after = int(os.environ.get("REPRO_KILL_AFTER_SEGMENTS", "0"))
        segments_done = []

        def on_segment(st, rounds_done):
            segments_done.append(rounds_done)
            if manager is not None:
                print(f"checkpoint step {rounds_done} -> {manager.directory}")
            if kill_after and len(segments_done) >= kill_after:
                print(f"REPRO_KILL_AFTER_SEGMENTS={kill_after}: SIGKILL", flush=True)
                os.kill(os.getpid(), signal.SIGKILL)

        start_round = int(state.round)
        t0 = time.time()
        state = run_segmented(
            state, rounds, segment,
            ckpt_every=ckpt_every, manager=manager, on_segment=on_segment,
        )
        jax.block_until_ready(state)
        wall = time.time() - t0
        params, s_state = state.params, state.sampler
        losses = np.asarray(state.metrics["loss"])
        cohorts = np.asarray(state.metrics["cohort_size"])
        for t in range(rounds):
            print(f"round {t:>3} loss={losses[t]:.4f} cohort={int(cohorts[t])}")
        n_disp = len(segments_done)
        disp = "one dispatch" if n_disp == 1 else f"{n_disp} dispatches"
        print(f"{rounds - start_round} rounds in {disp}: {wall:.1f}s "
              f"({wall / max(rounds - start_round, 1):.2f}s/round)")
        dropped_total = int(np.sum(np.asarray(state.metrics["dropped"])))
        if dropped_total:
            print(f"cohort overflow drops: {dropped_total}")
        if "deadline_dropped" in state.metrics:
            dd = int(np.sum(np.asarray(state.metrics["deadline_dropped"])))
            print(f"deadline straggler drops: {dd}")
        if ckpt:
            f = save_checkpoint(ckpt, {"params": params, "sampler": s_state})
            print("final checkpoint ->", f)
        return

    if rspec.faults is not None:
        raise SystemExit(
            "fault injection (FaultSpec enabled) requires --compiled: the "
            "fault state (availability chain, stale-delta buffer) lives in "
            "the scan carry, which the per-round host loop does not thread"
        )
    if rspec.compression is not None:
        raise SystemExit(
            "delta compression (--delta-dtype) requires --compiled: the "
            "error-feedback residual lives in the scan carry, which the "
            "per-round host loop does not thread"
        )
    round_step = jax.jit(build_round_step(cfg, rspec), donate_argnums=(0,))

    dropped_total = 0
    for t in range(rounds):
        t0 = time.time()
        key, k_draw, k_data = jax.random.split(key, 3)
        # Solve the sampling probabilities ONCE per round; the draw and the
        # log line both reuse this vector (the old loop solved 3x: sample +
        # two probabilities() calls in the print).
        p = sampler.probabilities(s_state)
        draw = sampler.sample_from(p, k_draw)
        w_full = estimator.client_weights(
            draw, jnp.asarray(lam), sampler.procedure, sampler.budget
        )
        # Shared padded-cohort contract: uniform overflow drop with |S|/C
        # weight rescaling (unbiased), inert zero padding — fed/cohort.py.
        sel = fed_cohort.select_cohort(
            draw.mask, w_full, rspec.cohort, jax.random.fold_in(k_draw, 1)
        )
        dropped_total += int(sel.n_dropped)

        # gather cohort batches (C, R, B, S); padding slots stay zero
        tokens, targets = fed_cohort.host_gather_cohort_batches(
            ds, sel, k_data, rspec.local_steps, rspec.local_batch
        )

        params, norms, loss = round_step(params, tokens, targets, sel.weights)

        # feedback: pi_t(i) = lambda_i ||g_i|| for the clients actually trained
        ids, valid = np.asarray(sel.ids), np.asarray(sel.valid)
        fb = np.zeros(ds.n_clients, np.float32)
        fb[ids[valid]] = lam[ids[valid]] * np.asarray(norms)[valid]
        s_state = sampler.update(s_state, draw, jnp.asarray(fb))

        print(
            f"round {t:>3} loss={float(loss):.4f} cohort={int(valid.sum())} "
            f"p[min/max]={float(jnp.min(p)):.3f}/{float(jnp.max(p)):.3f} "
            f"({time.time()-t0:.1f}s)"
        )
        if ckpt and ckpt_every and (t + 1) % ckpt_every == 0:
            # Host-loop snapshot: params+sampler ONLY (not resumable — no
            # RNG key or round index; use --compiled for real resume).
            f = save_checkpoint(f"{ckpt}_r{t+1}", {"params": params, "sampler": s_state})
            print("  checkpoint ->", f)

    if dropped_total:
        print(f"cohort overflow drops: {dropped_total}")
    if ckpt:
        f = save_checkpoint(ckpt, {"params": params, "sampler": s_state})
        print("final checkpoint ->", f)


def main(argv=None) -> None:
    ap = make_parser()
    args = ap.parse_args(argv)

    if args.spec:
        spec = ExperimentSpec.load(args.spec)
    else:
        spec = build_spec_from_args(args)

    if args.dump_spec:
        print(spec.to_json())
        return

    if args.lint:
        from repro.analysis.lint import run_suite

        report = run_suite(spec)
        print(report.render(), flush=True)
        if not report.ok:
            raise SystemExit(1)

    if args.resume and not spec.execution.compiled:
        ap.error(
            "--resume requires the compiled path (--compiled, or "
            '"execution": {"compiled": true} in --spec): host-loop '
            "checkpoints hold params+sampler only — no RNG key or round "
            "index — and cannot be resumed"
        )

    run_spec(spec, ckpt=args.ckpt, resume=args.resume)


if __name__ == "__main__":
    main()
