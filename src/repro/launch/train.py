"""End-to-end federated training driver: any zoo architecture x any sampler.

On a TPU slice this launches the production mesh; on CPU it runs the same
code path with a 1-device mesh and (typically) --reduced configs, e.g.:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --rounds 8 --clients 32 --budget 6 --sampler kvib --seq 64 --ckpt /tmp/fl

The driver is the deployable realization of Algorithm 1, in two modes:

* default (host loop): per-round Python dispatch —
    host: sampler state, ISP draw, cohort selection/padding via the shared
          ``repro.fed.cohort`` contract (probabilities solved ONCE per round,
          unbiased |S|/C overflow rescaling, inert zero padding)
    device: the jitted federated round step (local SGD + cohort-width
            weighted aggregation + feedback norms in one program)
* ``--compiled``: the run executes as jitted ``lax.scan`` *segments* over
  rounds (``fed.round.build_fed_scan_segment`` driven by
  ``fed.state.run_segmented``) on the host mesh from ``repro.launch.mesh`` —
  draw, selection, device-side batch gather, sharded round step, and sampler
  update all inside the trace; both modes consume the identical key stream,
  so they train on the same draws and batches.  ``--ckpt-every N`` cuts the
  horizon into N-round segments (bitwise-neutral) and, with ``--ckpt DIR``,
  publishes the full ``TrainState`` — params, sampler's learned state, metric
  buffers, round index, RNG key — through a ``CheckpointManager`` at every
  boundary; ``--resume`` restarts a SIGKILL'd run from the manifest and
  reproduces the uninterrupted run's results exactly
  (tests/test_launchers.py).
"""
from __future__ import annotations

import argparse
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, config_fingerprint, save_checkpoint
from repro.configs import get_config
from repro.core import estimator, make_sampler
from repro.data import synthetic_tokens
from repro.fed import cohort as fed_cohort
from repro.fed.round import RoundSpec, build_fed_scan_segment, build_round_step
from repro.fed.state import run_segmented
from repro.launch.mesh import make_host_mesh
from repro.models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--sampler", default="kvib")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--budget", type=int, default=6)
    ap.add_argument("--cohort", type=int, default=8, help="padded cohort buffer C")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument(
        "--ckpt-every", type=int, default=0,
        help="checkpoint every N rounds; with --compiled this is the scan "
        "segment length (bitwise-neutral) and checkpoints go to the "
        "<ckpt>_ckpts/ CheckpointManager directory",
    )
    ap.add_argument(
        "--compiled", action="store_true",
        help="run the rounds as jitted lax.scan segments on the host mesh "
        "(fed.round.build_fed_scan_segment); default is the per-round host loop",
    )
    ap.add_argument(
        "--resume", action="store_true",
        help="with --compiled --ckpt --ckpt-every: resume from the newest "
        "committed step in <ckpt>_ckpts/manifest.json (fresh start if none)",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    ds = synthetic_tokens(
        n_clients=args.clients, seq_len=args.seq, vocab=cfg.vocab,
        total_seqs=max(32 * args.clients, 512), seed=args.seed,
    )
    lam = np.asarray(ds.lam)

    params = transformer.init_params(cfg, key)
    n_params = transformer.param_count(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M clients={args.clients} "
          f"K={args.budget} cohort={args.cohort} sampler={args.sampler}")

    sampler = make_sampler(
        args.sampler, n=args.clients, budget=args.budget,
        **({"horizon": args.rounds} if args.sampler in ("kvib", "vrb") else {}),
    )
    s_state = sampler.init()

    spec = RoundSpec(
        cohort=args.cohort, local_steps=args.local_steps, local_lr=args.local_lr,
        local_batch=args.local_batch,
    )

    if args.compiled:
        mesh = make_host_mesh()
        print(f"compiled scan on mesh {dict(mesh.shape)} ({len(mesh.devices.flat)} devices)")
        segment, make_state = build_fed_scan_segment(cfg, spec, sampler, ds, mesh=mesh)
        # Identical key stream to the host loop below: per round
        # (key, k_draw, k_data) chained splits, derived in-trace segment by
        # segment from the TrainState's chain key.
        state = make_state(params, s_state, key, args.rounds)

        manager = None
        if args.resume and not (args.ckpt and args.ckpt_every):
            print("warning: --resume needs --ckpt AND --ckpt-every; starting fresh")
        if args.ckpt and args.ckpt_every:
            fingerprint = config_fingerprint({
                "arch": cfg.name, "reduced": args.reduced, "sampler": args.sampler,
                "rounds": args.rounds, "clients": args.clients,
                "budget": args.budget, "cohort": args.cohort,
                "local_steps": args.local_steps, "local_batch": args.local_batch,
                "seq": args.seq, "local_lr": args.local_lr, "seed": args.seed,
            })
            manager = CheckpointManager(f"{args.ckpt}_ckpts", fingerprint=fingerprint)
            if args.resume:
                state, start = manager.restore_or_init(state)
                if start:
                    print(f"resumed from checkpoint step {start} "
                          f"({args.rounds - start} rounds remaining)")

        # Test hook: self-SIGKILL after N published segments — how the
        # kill/resume integration test simulates a preemption that strikes
        # between segment boundaries.
        kill_after = int(os.environ.get("REPRO_KILL_AFTER_SEGMENTS", "0"))
        segments_done = []

        def on_segment(st, rounds_done):
            segments_done.append(rounds_done)
            if manager is not None:
                print(f"checkpoint step {rounds_done} -> {manager.directory}")
            if kill_after and len(segments_done) >= kill_after:
                print(f"REPRO_KILL_AFTER_SEGMENTS={kill_after}: SIGKILL", flush=True)
                os.kill(os.getpid(), signal.SIGKILL)

        start_round = int(state.round)
        t0 = time.time()
        state = run_segmented(
            state, args.rounds, segment,
            ckpt_every=args.ckpt_every, manager=manager, on_segment=on_segment,
        )
        jax.block_until_ready(state)
        wall = time.time() - t0
        params, s_state = state.params, state.sampler
        losses = np.asarray(state.metrics["loss"])
        cohorts = np.asarray(state.metrics["cohort_size"])
        for t in range(args.rounds):
            print(f"round {t:>3} loss={losses[t]:.4f} cohort={int(cohorts[t])}")
        n_disp = len(segments_done)
        disp = "one dispatch" if n_disp == 1 else f"{n_disp} dispatches"
        print(f"{args.rounds - start_round} rounds in {disp}: {wall:.1f}s "
              f"({wall / max(args.rounds - start_round, 1):.2f}s/round)")
        dropped_total = int(np.sum(np.asarray(state.metrics["dropped"])))
        if dropped_total:
            print(f"cohort overflow drops: {dropped_total}")
        if args.ckpt:
            f = save_checkpoint(args.ckpt, {"params": params, "sampler": s_state})
            print("final checkpoint ->", f)
        return

    round_step = jax.jit(build_round_step(cfg, spec), donate_argnums=(0,))

    dropped_total = 0
    for t in range(args.rounds):
        t0 = time.time()
        key, k_draw, k_data = jax.random.split(key, 3)
        # Solve the sampling probabilities ONCE per round; the draw and the
        # log line both reuse this vector (the old loop solved 3x: sample +
        # two probabilities() calls in the print).
        p = sampler.probabilities(s_state)
        draw = sampler.sample_from(p, k_draw)
        w_full = estimator.client_weights(
            draw, jnp.asarray(lam), sampler.procedure, sampler.budget
        )
        # Shared padded-cohort contract: uniform overflow drop with |S|/C
        # weight rescaling (unbiased), inert zero padding — fed/cohort.py.
        sel = fed_cohort.select_cohort(
            draw.mask, w_full, args.cohort, jax.random.fold_in(k_draw, 1)
        )
        dropped_total += int(sel.n_dropped)

        # gather cohort batches (C, R, B, S); padding slots stay zero
        tokens, targets = fed_cohort.host_gather_cohort_batches(
            ds, sel, k_data, args.local_steps, args.local_batch
        )

        params, norms, loss = round_step(params, tokens, targets, sel.weights)

        # feedback: pi_t(i) = lambda_i ||g_i|| for the clients actually trained
        ids, valid = np.asarray(sel.ids), np.asarray(sel.valid)
        fb = np.zeros(args.clients, np.float32)
        fb[ids[valid]] = lam[ids[valid]] * np.asarray(norms)[valid]
        s_state = sampler.update(s_state, draw, jnp.asarray(fb))

        print(
            f"round {t:>3} loss={float(loss):.4f} cohort={int(valid.sum())} "
            f"p[min/max]={float(jnp.min(p)):.3f}/{float(jnp.max(p)):.3f} "
            f"({time.time()-t0:.1f}s)"
        )
        if args.ckpt and args.ckpt_every and (t + 1) % args.ckpt_every == 0:
            f = save_checkpoint(f"{args.ckpt}_r{t+1}", {"params": params, "sampler": s_state})
            print("  checkpoint ->", f)

    if dropped_total:
        print(f"cohort overflow drops: {dropped_total}")
    if args.ckpt:
        f = save_checkpoint(args.ckpt, {"params": params, "sampler": s_state})
        print("final checkpoint ->", f)


if __name__ == "__main__":
    main()
