"""Parameter / input / cache sharding assignment for the production meshes.

Param specs are assigned by leaf *name* (the pytree key carries the role):
expanding projections shard their output-features over `model`, contracting
projections their input-features; MoE expert stacks shard the expert axis;
FSDP mode additionally scatters the d_model-ish axis over the batch axes
(('data',) single-pod, ('pod','data') multi-pod).  Anything non-divisible or
unknown stays replicated — GSPMD correctness never depends on these hints,
only efficiency does.

Cache specs are heuristic by shape: the sequence axis (== max_seq) shards
over the kv_seq axes, the batch axis over the batch axes, otherwise the
largest mesh-divisible trailing dim goes to `model`.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes, fsdp_axes

__all__ = ["param_specs", "param_shardings", "cache_shardings", "activation_rules"]

# leaf-name -> role
_EXPAND = {"wq", "wk", "wv", "up", "gate", "in_proj", "w_in", "ffn_up", "ffn_gate", "w_if", "qkv"}
_CONTRACT = {"wo", "down", "out_proj", "ffn_down"}
_MOE_IN = {"w_gate", "w_up"}  # (L, E, d, f)
_MOE_OUT = {"w_down"}  # (L, E, f, d)


def _divides(n: int, axes: tuple, mesh) -> bool:
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return n % size == 0 and n >= size


def _spec_for(name: str, shape: tuple, mesh, fsdp: bool) -> P:
    model = "model"
    fs = fsdp_axes(mesh) if fsdp else None
    nd = len(shape)

    def pad(trailing: tuple) -> P:
        return P(*((None,) * (nd - len(trailing)) + trailing))

    if name == "embed" and nd == 2:
        vocab_ok = _divides(shape[0], ("model",), mesh)
        d_ok = fs is not None and _divides(shape[1], fs, mesh)
        return P(model if vocab_ok else None, fs if d_ok else None)
    if name == "lm_head" and nd == 2:
        d_ok = fs is not None and _divides(shape[0], fs, mesh)
        vocab_ok = _divides(shape[1], ("model",), mesh)
        return P(fs if d_ok else None, model if vocab_ok else None)
    if name in _MOE_IN and nd >= 3:
        e_ok = _divides(shape[-3], ("model",), mesh)
        d_ok = fs is not None and _divides(shape[-2], fs, mesh)
        return pad((model if e_ok else None, fs if d_ok else None, None))
    if name in _MOE_OUT and nd >= 3:
        e_ok = _divides(shape[-3], ("model",), mesh)
        d_ok = fs is not None and _divides(shape[-1], fs, mesh)
        return pad((model if e_ok else None, None, fs if d_ok else None))
    if name == "router" and nd >= 2:
        return pad((None, model if _divides(shape[-1], ("model",), mesh) else None))
    if name in _EXPAND and nd >= 2:
        out_ok = _divides(shape[-1], ("model",), mesh)
        in_ok = fs is not None and _divides(shape[-2], fs, mesh)
        return pad((fs if in_ok else None, model if out_ok else None))
    if name in _CONTRACT and nd >= 2:
        in_ok = _divides(shape[-2], ("model",), mesh)
        out_ok = fs is not None and _divides(shape[-1], fs, mesh)
        return pad((model if in_ok else None, fs if out_ok else None))
    if name == "conv_w" and nd >= 2:
        return pad((model if _divides(shape[-1], ("model",), mesh) else None,))
    # norms, biases, scalars, pos embeddings, small recurrent mats: replicated
    return P()


def param_specs(params, mesh, fsdp: bool):
    """Pytree of PartitionSpecs mirroring `params` (works on shapes too)."""

    def assign(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        return _spec_for(name or "", tuple(leaf.shape), mesh, fsdp)

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(params, mesh, fsdp: bool):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params, mesh, fsdp)
    )


def cache_shardings(caches, mesh, max_seq: int, batch: int):
    """Heuristic decode-cache shardings (see module docstring)."""
    b_axes = batch_axes(mesh)
    b_size = int(np.prod([mesh.shape[a] for a in b_axes]))
    m_size = mesh.shape["model"]

    def assign(leaf):
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        used_model = used_batch = False
        # dim 0 is the pattern-repeat stack: never sharded.
        for i, s in enumerate(shape):
            if i == 0:
                continue
            if s == max_seq and not used_model:
                # the long axis: kv_seq -> model (+ batch axes when batch==1)
                if batch == 1 and s % (b_size * m_size) == 0:
                    spec[i] = b_axes + ("model",)
                elif s % m_size == 0:
                    spec[i] = "model"
                used_model = True
            elif s == batch and not used_batch and batch % b_size == 0:
                spec[i] = b_axes
                used_batch = True
        # if the long axis didn't claim `model`, give it to the largest
        # divisible unassigned trailing dim (SSM head/state axes etc.)
        if not used_model:
            cand = [
                (s, i)
                for i, s in enumerate(shape)
                if i > 0 and spec[i] is None and s % m_size == 0
            ]
            if cand:
                _, i = max(cand)
                spec[i] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(assign, caches)


def activation_rules(mesh, *, long_context: bool = False, client_parallel: bool = False) -> dict:
    b_axes = batch_axes(mesh)
    rules = {
        # client_parallel vmaps the model over the cohort: the *client* dim
        # carries the batch axes and the inner per-client batch must stay
        # unconstrained or it fights GSPMD propagation across the vmap.
        "batch": None if client_parallel else b_axes,
        "clients": b_axes,
        "heads": ("model",),
        "kv_heads": None,  # kv head counts are small (4-16); keep replicated
        "ffn": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "embed": None,
        "seq": None,
        "kv_seq": b_axes + ("model",) if long_context else ("model",),
        "state": ("model",),
    }
    return rules
