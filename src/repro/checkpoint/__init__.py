"""Preemption-safe checkpointing for the segmented compiled horizon.

The K-Vib sampler's value is its *online* state — losing it to a preemption
loses the learned sampling probabilities, not just wall-clock.  This package
is the persistence layer under ``repro.fed.state.run_segmented``: the compiled
training horizon runs as jitted scan segments, and every segment boundary
round-trips the canonical carry through a step-numbered checkpoint here.

Layout and manifest spec
------------------------

A checkpoint *directory* managed by ``CheckpointManager`` contains::

    manifest.json                  commit point — written (tmp + os.replace)
                                   strictly AFTER the files it references
    <name>_<step:08d>.npz          flat array leaves, keyed ``leaf_<i>`` in
                                   tree_flatten order; atomic tmp + replace
    <name>_<step:08d>.treedef.txt  str(jax.tree_util.tree_structure) sidecar;
                                   atomic tmp + replace

``manifest.json`` fields::

    format              manifest schema version (currently 1)
    name                checkpoint basename prefix
    step                newest committed step (the resume point)
    file                basename of that step's .npz
    steps               retained steps, oldest -> newest (``keep_last`` bound)
    treedef_sha256      sha256[:16] of the newest step's treedef string
    config_fingerprint  ``config_fingerprint(run config)`` or null — resuming
                        under a different fingerprint raises
    versions            {jax, numpy, python} that wrote the checkpoint

Crash anywhere mid-save and the manifest still references the previous
fully-published step: a torn npz/sidecar pair can exist on disk but never be
*reachable* through ``latest()`` / ``restore_or_init()``.

What must be in the carry
-------------------------

Restore is template-shaped: the reader builds the fresh initial state and the
checkpoint refills it, so everything a resumed process needs must be an array
leaf of the saved pytree (``repro.fed.state.TrainState`` is the canonical
carry — see its module docstring, mirroring ``fed/cohort.py``'s "Aggregation
width" contract section):

* model ``params`` and server-optimizer ``opt_state``;
* the sampler's online state — a flat pytree of arrays, round counter
  included as an int32 *array* (``core.samplers`` serializable-state
  contract: no Python scalars smuggled into carries, they would vanish from
  checkpoints and be baked into traces as constants);
* the on-device ``(T, ...)`` metric buffers, so a resumed run's ``History``
  covers rounds executed before the preemption;
* the scalar ``round`` index and the PRNG ``key`` from which the remaining
  rounds' keys derive.

Restore validates structure (treedef string), per-leaf shape AND dtype — any
mismatch raises; nothing is silently cast.
"""
from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint
from repro.checkpoint.manager import CheckpointManager, config_fingerprint

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "CheckpointManager",
    "config_fingerprint",
]
