"""Step-numbered checkpoint management with an atomic JSON manifest.

``CheckpointManager`` turns the flat ``save_checkpoint``/``restore_checkpoint``
pair into a preemption-safe subsystem for the segmented compiled horizon
(``repro.fed.state.run_segmented``): every segment boundary publishes a
step-numbered checkpoint, the manifest write is the atomic commit point, and
a restarted process discovers where to resume via ``latest()`` /
``restore_or_init()``.

Directory layout (``repro.checkpoint`` package docstring has the full spec)::

    <dir>/manifest.json                  the commit point (tmp + os.replace)
    <dir>/<name>_<step:08d>.npz          flat arrays, atomic
    <dir>/<name>_<step:08d>.treedef.txt  str(treedef) sidecar, atomic

Because the manifest is written strictly AFTER its checkpoint files, a crash
anywhere mid-save leaves the manifest pointing at the previous fully-published
step — a torn pair can exist on disk but can never be *referenced*.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import time
from typing import Any

import jax
import numpy as np

from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint

__all__ = ["CheckpointManager", "config_fingerprint"]

_MANIFEST_FORMAT = 1


def config_fingerprint(config: Any) -> str:
    """Stable short fingerprint of a run configuration.

    The canonical input is ``repro.api.ExperimentSpec`` (or its
    ``to_dict()``): the spec is the one serializable description of a run,
    so its fingerprint is the manifest's compatibility guard — ANY spec
    field change yields a different fingerprint.  Also accepts anything
    JSON-serializable-ish (objects with ``to_dict()`` are converted through
    it, dataclasses via ``dataclasses.asdict``; unknown leaves fall back to
    ``repr``).  Two processes agreeing on the fingerprint is the manager's
    guard against resuming a run under a silently different configuration."""
    if hasattr(config, "to_dict"):
        config = config.to_dict()
    elif dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    blob = json.dumps(config, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _treedef_hash(state) -> str:
    treedef = jax.tree_util.tree_structure(state)
    return hashlib.sha256(str(treedef).encode()).hexdigest()[:16]


class CheckpointManager:
    """Step-numbered atomic checkpoints + manifest + retention + discovery.

    Parameters
    ----------
    directory:
        Where checkpoints and the manifest live (created on first use).
    keep_last:
        Retain the newest ``keep_last`` steps; older checkpoint files are
        deleted when a new step is published (the manifest's ``steps`` list
        is the authoritative record of what is retained).
    fingerprint:
        Optional ``config_fingerprint(...)`` of the run configuration.  It is
        recorded in the manifest on save and validated on restore: resuming
        with a different fingerprint raises instead of silently mixing
        configurations (segment boundaries, key streams, and metric-buffer
        shapes are all config-derived).
    name:
        Basename prefix for checkpoint files.
    """

    def __init__(
        self,
        directory: str,
        *,
        keep_last: int = 3,
        fingerprint: str | None = None,
        name: str = "state",
        layout=None,
    ):
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        self.directory = str(directory)
        self.keep_last = int(keep_last)
        self.fingerprint = fingerprint
        self.name = name
        # Optional repro.launch.mesh.ShardSpec describing the saving run's
        # sampler (N,)-axis layout.  Recorded in the manifest as PROVENANCE,
        # never validated on restore: checkpoints round-trip through host
        # numpy, so a restoring process lays the arrays out per its OWN
        # ShardSpec — resuming onto a different mesh shape is legal.
        self.layout = layout

    # -- paths ---------------------------------------------------------------
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.directory, "manifest.json")

    def checkpoint_path(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.name}_{int(step):08d}.npz")

    # -- manifest ------------------------------------------------------------
    def read_manifest(self) -> dict | None:
        """The committed manifest dict, or None if nothing was ever published."""
        try:
            with open(self.manifest_path) as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def _write_manifest(self, manifest: dict) -> None:
        os.makedirs(self.directory, exist_ok=True)
        tmp = self.manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
        os.replace(tmp, self.manifest_path)  # the atomic commit point

    # -- save / discover / restore -------------------------------------------
    def save(self, state, step: int) -> str:
        """Publish ``state`` as step ``step``: files first, then the manifest.

        Returns the checkpoint ``.npz`` path.  Applies retention after the
        manifest commit (deleting a stale file can never un-commit a step)."""
        step = int(step)
        fname = save_checkpoint(self.checkpoint_path(step), state)
        prev = self.read_manifest()
        steps = sorted(set((prev.get("steps", []) if prev else [])) | {step})
        retained = steps[-self.keep_last :]
        manifest = {
            "format": _MANIFEST_FORMAT,
            "name": self.name,
            "step": max(retained),
            "file": os.path.basename(fname),
            "steps": retained,
            "treedef_sha256": _treedef_hash(state),
            "config_fingerprint": self.fingerprint,
            "shard_layout": (
                self.layout.to_manifest() if self.layout is not None else None
            ),
            "versions": {
                "jax": jax.__version__,
                "numpy": np.__version__,
                "python": platform.python_version(),
            },
        }
        self._write_manifest(manifest)
        for stale in steps[: -self.keep_last]:
            for path in (
                self.checkpoint_path(stale),
                self.checkpoint_path(stale)[: -len(".npz")] + ".treedef.txt",
            ):
                try:
                    os.remove(path)
                except FileNotFoundError:
                    pass
        return fname

    def latest(self) -> int | None:
        """Newest committed step whose checkpoint file exists, else None."""
        manifest = self.read_manifest()
        if manifest is None:
            return None
        for step in sorted(manifest.get("steps", [manifest["step"]]), reverse=True):
            if os.path.exists(self.checkpoint_path(step)):
                return int(step)
        return None

    def wait_for_next(
        self,
        after_step: int,
        timeout: float,
        *,
        poll_interval: float = 0.05,
    ) -> int | None:
        """Block until a step > ``after_step`` is committed; return it.

        The read side of the hand-off contract for a *concurrently writing*
        manager (a training process publishing boundaries while a serving
        process follows — ``repro.serve.CheckpointWatcher``):

        * Readers can never observe a partially written step.  ``save``
          writes the checkpoint files first and the manifest last, and the
          manifest lands via tmp-file + ``os.replace`` — POSIX-atomic, so a
          concurrent ``read_manifest`` sees either the previous complete
          manifest or the new complete one, never a torn JSON, and any step
          the manifest references already has its files fully on disk.
        * ``latest()`` additionally requires the step's ``.npz`` to exist,
          so a retention race (the writer deleting a stale step between the
          manifest read and the file check) degrades to the next-newest
          retained step, never to a dangling reference.

        Polls ``latest()`` every ``poll_interval`` seconds; returns the
        newest committed step ``> after_step`` as soon as one is visible, or
        ``None`` once ``timeout`` seconds elapse without one.  ``timeout=0``
        is a single non-blocking check."""
        after = int(after_step)
        deadline = time.monotonic() + float(timeout)
        while True:
            step = self.latest()
            if step is not None and step > after:
                return int(step)
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            time.sleep(min(float(poll_interval), remaining))

    def restore(self, template, step: int | None = None):
        """Restore step ``step`` (default: ``latest()``) into ``template``.

        Validates, in order: the manifest's config fingerprint against this
        manager's (when both are set), the manifest's treedef hash against
        the template's, then ``restore_checkpoint``'s own treedef-string /
        shape / dtype checks against the files themselves."""
        manifest = self.read_manifest()
        if manifest is None:
            raise FileNotFoundError(f"no manifest under {self.directory!r}")
        if step is None:
            step = self.latest()
            if step is None:
                raise FileNotFoundError(
                    f"manifest exists but no checkpoint files under {self.directory!r}"
                )
        saved_fp = manifest.get("config_fingerprint")
        if self.fingerprint and saved_fp and saved_fp != self.fingerprint:
            raise ValueError(
                f"config fingerprint mismatch: checkpoint was written by a run "
                f"with fingerprint {saved_fp}, this run has {self.fingerprint} "
                "— refusing to resume under a different configuration"
            )
        if int(step) == manifest["step"]:
            want = _treedef_hash(template)
            have = manifest.get("treedef_sha256")
            if have and have != want:
                raise ValueError(
                    f"treedef hash mismatch: manifest has {have}, template "
                    f"hashes to {want} — the carry structure changed"
                )
        return restore_checkpoint(self.checkpoint_path(int(step)), template)

    def restore_or_init(self, template):
        """(state, step): the latest committed state, or (template, 0) fresh.

        The standard resume entry point: build the fresh initial state as the
        template, then continue from wherever the manifest says the previous
        process got to — or from round 0 if it never published anything."""
        step = self.latest()
        if step is None:
            return template, 0
        return self.restore(template, step), int(step)
