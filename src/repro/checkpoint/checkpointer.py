"""Minimal dependency-free checkpointing: pytrees -> flat npz + tree spec.

Saves model params, server-optimizer state, and sampler state (the K-Vib
cumulative feedback omega is part of the training state — a restarted server
must not forget what it learned about clients).

Layout:  <dir>/<name>.npz          flat arrays keyed by index
         <dir>/<name>.treedef.txt  str(jax.tree_util.tree_structure)
Restore requires a template pytree with matching structure (the standard
"abstract state" pattern); arrays are checked for shape/dtype drift.
"""
from __future__ import annotations

import os

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint"]


def save_checkpoint(path: str, state) -> str:
    """Write `state` (any pytree of arrays) to `<path>.npz`. Returns the file."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    fname = path if path.endswith(".npz") else path + ".npz"
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, fname)  # atomic publish
    with open(fname.replace(".npz", ".treedef.txt"), "w") as f:
        f.write(str(treedef))
    return fname


def restore_checkpoint(path: str, template):
    """Restore into the structure of `template`; validates shapes/dtypes."""
    fname = path if path.endswith(".npz") else path + ".npz"
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    with np.load(fname) as data:
        n = len(data.files)
        if n != len(leaves_t):
            raise ValueError(
                f"checkpoint has {n} leaves, template has {len(leaves_t)}"
            )
        leaves = []
        for i, t in enumerate(leaves_t):
            arr = data[f"leaf_{i}"]
            t_arr = np.asarray(t)
            if arr.shape != t_arr.shape:
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != template {t_arr.shape}"
                )
            leaves.append(arr.astype(t_arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
