"""Minimal dependency-free checkpointing: pytrees -> flat npz + tree spec.

Saves model params, server-optimizer state, and sampler state (the K-Vib
cumulative feedback omega is part of the training state — a restarted server
must not forget what it learned about clients).

Layout:  <dir>/<name>.npz          flat arrays keyed by index
         <dir>/<name>.treedef.txt  str(jax.tree_util.tree_structure)
Both files are published atomically (tmp + ``os.replace``) so a crash mid-save
can never leave a half-written file under the final name.  Restore requires a
template pytree with matching structure (the standard "abstract state"
pattern); the saved treedef string, every leaf's shape, AND every leaf's dtype
are validated against the template — a mismatch raises instead of silently
casting, because a dtype drift between writer and reader is a config drift,
not a convertible format difference.

Step-numbered checkpoints, manifests, retention, and ``latest()`` discovery
live one level up in ``repro.checkpoint.manager.CheckpointManager``.
"""
from __future__ import annotations

import os

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint"]


def _sidecar_path(fname: str) -> str:
    return fname[: -len(".npz")] + ".treedef.txt"


def save_checkpoint(path: str, state) -> str:
    """Write `state` (any pytree of arrays) to `<path>.npz`. Returns the file."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    fname = path if path.endswith(".npz") else path + ".npz"
    sidecar = _sidecar_path(fname)
    # Stage BOTH files before publishing EITHER: a crash can leave stale tmp
    # files but never a half-written .npz or .treedef.txt under its final name.
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    tmp_sidecar = sidecar + ".tmp"
    with open(tmp_sidecar, "w") as f:
        f.write(str(treedef))
    os.replace(tmp, fname)  # atomic publish
    os.replace(tmp_sidecar, sidecar)  # atomic publish
    return fname


def restore_checkpoint(path: str, template):
    """Restore into the structure of `template`.

    Validates the saved treedef string against the template's and every
    leaf's shape and dtype — any mismatch raises ``ValueError`` (dtypes are
    NOT silently cast; see module docstring).
    """
    fname = path if path.endswith(".npz") else path + ".npz"
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    with open(_sidecar_path(fname)) as f:
        saved_treedef = f.read()
    if saved_treedef != str(treedef):
        raise ValueError(
            "checkpoint treedef does not match template structure:\n"
            f"  saved:    {saved_treedef}\n  template: {treedef}"
        )
    with np.load(fname) as data:
        n = len(data.files)
        if n != len(leaves_t):
            raise ValueError(
                f"checkpoint has {n} leaves, template has {len(leaves_t)}"
            )
        leaves = []
        for i, t in enumerate(leaves_t):
            arr = data[f"leaf_{i}"]
            t_arr = np.asarray(t)
            if arr.shape != t_arr.shape:
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != template {t_arr.shape}"
                )
            if arr.dtype != t_arr.dtype:
                raise ValueError(
                    f"leaf {i}: checkpoint dtype {arr.dtype} != template "
                    f"{t_arr.dtype} (refusing to cast silently)"
                )
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
