"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts each while-loop body ONCE, but this
framework is scan-based everywhere (layer stacks, local SGD steps, cohort
scans, recurrent cells) — naive costs undercount by orders of magnitude.
This walker parses the post-SPMD HLO text and accounts properly:

* builds the computation call graph (while bodies via ``body=%B`` with
  ``known_trip_count``; fusions/reductions via ``calls=``/``to_apply=``),
* propagates execution multiplicity from ENTRY through the DAG,
* counts per computation:
    - dot FLOPs        2 * prod(result_shape) * prod(contracting dims)
    - collective bytes  result payload of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute
    - HBM bytes         operands + result of *memory-materializing* ops only
                        (dots, fusions, reduces, gathers/scatters, cache
                        slice updates).  Pure data-movement artifacts of the
                        CPU backend (copies, transposes, broadcasts, loop
                        plumbing) are excluded: on the TPU target those fuse
                        into neighbors, and the perf-critical softmax/SSD
                        paths ship as Pallas kernels that never spill
                        intermediates to HBM.  This is a *structural traffic
                        model*, consistent across configs.

All quantities are PER-DEVICE (the SPMD module is the per-device program).
Validated against closed-form expectations in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "DTYPE_BYTES", "UnknownDtypeError", "dtype_bytes"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}


class UnknownDtypeError(KeyError):
    """An HLO dtype token with no ``DTYPE_BYTES`` entry.

    Raised (instead of a bare ``KeyError`` whose message is just the token)
    when a shape regex built from an extended dtype table meets the original
    byte table — the fix is adding the dtype's width to ``DTYPE_BYTES``."""

    def __init__(self, dtype: str):
        super().__init__(dtype)
        self.dtype = dtype

    def __str__(self) -> str:
        return (
            f"unknown HLO dtype {self.dtype!r}: not in "
            "repro.analysis.hlo.DTYPE_BYTES — add its byte width there "
            f"(known: {sorted(DTYPE_BYTES)})"
        )


def dtype_bytes(dtype: str) -> int:
    """Byte width of an HLO dtype token; ``UnknownDtypeError`` if unmapped."""
    try:
        return DTYPE_BYTES[dtype]
    except KeyError:
        raise UnknownDtypeError(dtype) from None

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^\(?[\w\[\],{}\s]*?\)?\s*([a-z][\w\-]*)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

# Ops whose operand+result bytes count as HBM traffic (the structural
# traffic model — see module docstring).
_HBM_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "sort",
    "concatenate", "pad", "select-and-scatter", "cholesky",
    "triangular-solve", "fft", "rng", "rng-bit-generator",
}


def _shape_elems(type_str: str):
    """[(dtype, numel), ...] for every array in a (possibly tuple) type."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dtype, n))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(dtype_bytes(d) * n for d, n in _shape_elems(type_str))


class _Op:
    __slots__ = ("name", "rtype", "opname", "rest")

    def __init__(self, name, rtype, opname, rest):
        self.name, self.rtype, self.opname, self.rest = name, rtype, opname, rest


def _parse(text: str):
    """-> {comp_name: [Op, ...]}"""
    comps: dict[str, list] = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        hdr = _COMP_HDR.match(s)
        if hdr and (s.endswith("{")):
            cur = hdr.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = leading shape tokens before the op name
        om = re.search(r"\b([a-z][\w\-]*)\(", rhs)
        opname = om.group(1) if om else ""
        rtype = rhs[: om.start()] if om else rhs
        comps[cur].append(_Op(name, rtype, opname, rhs))
    return comps


def _dot_flops(op: _Op, symtab: dict) -> float:
    result_elems = sum(n for _, n in _shape_elems(op.rtype))
    cm = _CONTRACT_RE.search(op.rest)
    if not cm:
        return 2.0 * result_elems  # degenerate
    # lhs operand shape
    paren = op.rest[op.rest.find("(") + 1 :]
    ops_names = _OPERAND_RE.findall(paren.split(")")[0])
    k = 1
    if ops_names:
        lhs_type = symtab.get(ops_names[0], "")
        dims_m = _SHAPE_RE.search(lhs_type)
        if dims_m and dims_m.group(2):
            lhs_dims = [int(d) for d in dims_m.group(2).split(",")]
            for ci in cm.group(1).split(","):
                if ci:
                    k *= lhs_dims[int(ci)]
    return 2.0 * result_elems * k


def analyze_hlo(text: str) -> dict:
    comps = _parse(text)
    if not comps:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0, "collectives": {}}

    # symbol tables (shapes of named values) per computation
    symtabs = {c: {op.name: op.rtype for op in ops} for c, ops in comps.items()}

    # entry = computation named like main / last ENTRY parsed; HLO text marks
    # ENTRY but we stripped it — find computation not referenced anywhere.
    referenced = set()
    edges: list[tuple[str, str, float]] = []  # (caller, callee, factor)
    inlined = set()  # fusion/reduction sub-computations (no HBM accounting)
    for cname, ops in comps.items():
        for op in ops:
            if op.opname == "while":
                bm, cm_ = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
                tm = _TRIP_RE.search(op.rest)
                trip = float(tm.group(1)) if tm else 1.0
                if bm:
                    edges.append((cname, bm.group(1), trip))
                    referenced.add(bm.group(1))
                if cm_:
                    edges.append((cname, cm_.group(1), trip))
                    referenced.add(cm_.group(1))
            else:
                for callee in _CALLS_RE.findall(op.rest):
                    factor = 1.0
                    edges.append((cname, callee, factor))
                    referenced.add(callee)
                    if op.opname in ("fusion", "reduce", "map", "scatter", "select-and-scatter", "sort", "reduce-window", "all-reduce"):
                        inlined.add(callee)

    # classify callee computations: "trivial" = short pure-elementwise chains
    # that fuse into neighbors on the TPU target (no HBM round trip).
    _EW = {
        "add", "multiply", "subtract", "divide", "exponential", "tanh", "log",
        "log-plus-one", "exponential-minus-one", "maximum", "minimum",
        "compare", "select", "convert", "negate", "abs", "rsqrt", "sqrt",
        "power", "and", "or", "not", "xor", "floor", "ceil", "sign",
        "broadcast", "reshape", "bitcast", "copy", "transpose", "iota",
        "constant", "parameter", "get-tuple-element", "tuple", "clamp",
        "is-finite", "atan2", "cosine", "sine", "logistic", "tan",
        "shift-left", "shift-right-logical", "shift-right-arithmetic",
        "remainder", "round-nearest-afz", "round-nearest-even", "cbrt",
        "expm1", "log1p", "erf", "real", "imag", "partition-id",
    }
    trivial = set()
    has_dus = set()  # callees containing dynamic-update-slice (scan stacking)
    has_ds = set()  # callees containing dynamic-slice (scan reads)
    for cname, ops in comps.items():
        real_ops = [op for op in ops if op.opname not in ("parameter", "constant")]
        if len(real_ops) <= 8 and all(op.opname in _EW for op in ops):
            trivial.add(cname)
        kinds = {op.opname for op in ops}
        if "dynamic-update-slice" in kinds:
            has_dus.add(cname)
        if "dynamic-slice" in kinds:
            has_ds.add(cname)

    entries = [c for c in comps if c not in referenced]
    mult: dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e] = 1.0
    # propagate (graph is a DAG; iterate to fixpoint, small depth)
    for _ in range(64):
        changed = False
        acc: dict[str, float] = defaultdict(float)
        for e in entries:
            acc[e] = 1.0
        for caller, callee, factor in edges:
            acc[callee] += mult.get(caller, 0.0) * factor
        for k, v in acc.items():
            if abs(v - mult.get(k, 0.0)) > 1e-9:
                changed = True
        mult = acc
        if not changed:
            break

    flops = 0.0
    hbm_bytes = 0.0
    coll: dict[str, float] = defaultdict(float)
    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = symtabs[cname]
        is_inlined = cname in inlined
        for op in ops:
            base = op.opname.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.opname.endswith("-done"):
                    continue  # payload counted at -start
                coll[base] += _bytes_of(op.rtype) * m
                continue
            if op.opname == "dot":
                flops += _dot_flops(op, symtab) * m
            if is_inlined or op.opname not in _HBM_OPS:
                continue
            callees = _CALLS_RE.findall(op.rest) if op.opname == "fusion" else []
            if callees and all(c in trivial for c in callees):
                continue  # fuses into neighbors on the TPU target
            # HBM traffic at fusion granularity: result + named operands.
            # Tuple-typed operands are loop plumbing (the while carry), not
            # data reads; in-place accumulators (scan stacking via
            # dynamic-update-slice, carry copies) touch only the updated
            # slice; dynamic-slice reads touch only the extracted slice.
            rbytes = _bytes_of(op.rtype)
            operand_bytes = []
            paren = op.rest[op.rest.find("(") + 1 :]
            for oname in _OPERAND_RE.findall(paren.split(")")[0]):
                t = symtab.get(oname, "")
                if t.lstrip().startswith("("):
                    continue  # tuple plumbing
                operand_bytes.append(_bytes_of(t))
            in_place = (
                op.opname == "dynamic-update-slice"
                or "dynamic-update-slice" in op.name
                or "copy" in op.name
                or any(c in has_dus for c in callees)
            )
            slicing = (
                op.opname == "dynamic-slice"
                or "dynamic-slice" in op.name
                or any(c in has_ds for c in callees)
            )
            if in_place and rbytes in operand_bytes:
                operand_bytes.remove(rbytes)
                b = 2 * sum(operand_bytes)  # read update + write slice
            elif slicing:
                # sliced reads: big operands are accessed at ~result size
                b = rbytes + sum(min(ob, rbytes) for ob in operand_bytes)
            else:
                b = rbytes + sum(operand_bytes)
            hbm_bytes += b * m

    total_coll = sum(coll.values())
    return {
        "flops": flops,
        "bytes": hbm_bytes,
        "collective_bytes": total_coll,
        "collectives": dict(coll),
        "n_computations": len(comps),
    }


# Back-compat shim used by earlier callers/tests.
def collective_bytes(text: str) -> dict:
    res = analyze_hlo(text)
    out = dict(res["collectives"])
    out["total"] = res["collective_bytes"]
    return out
