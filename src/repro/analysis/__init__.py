from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import HW, RooflineTerms, model_flops, roofline

__all__ = ["analyze_hlo", "HW", "RooflineTerms", "model_flops", "roofline"]
