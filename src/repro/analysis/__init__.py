"""Static analysis of the compiled federated programs.

Two layers:

* cost models — ``analyze_hlo`` (trip-count-aware HLO FLOP/byte/collective
  walker) and ``roofline`` (hardware projection of those counts);
* the trace-invariant lint suite — ``repro.analysis.lint``, which walks
  jaxprs and post-SPMD HLO and machine-checks the structural contracts the
  repo's performance claims rest on.

Enforced trace invariants (``repro.analysis.lint``)
---------------------------------------------------

* **width** — the deployable round body and the pod-scale scan body
  aggregate at cohort width: no floating intermediate scales as O(N*D)
  (client count x parameter dimension).  Legitimate N-sized tensors are
  (N,)-vectors (sampler probabilities, feedback, weights) and integer
  key/index material.  ``audit_width`` (jaxpr) / ``audit_width_hlo``
  (post-SPMD compiled HLO).
* **scan-safety** — every registered ``Sampler``'s ``probabilities`` /
  ``sample_from`` / ``update`` traces abstractly: no data-dependent Python
  control flow, no host callbacks (``pure_callback`` / ``io_callback`` /
  ``debug_callback``), static shapes, and ``update`` preserves the state's
  avals exactly (the scan-carry contract).  ``audit_scan_safety``.
* **dtype** — no silent float64/complex128 promotion anywhere in the traced
  graph, and no weak-typed outputs (weak types are erased by checkpoint
  round trips, changing carry avals on resume).  ``audit_dtypes``; fed by
  ``core.samplers.assert_serializable_state``'s leaf-level checks.
* **compile-once** — the segmented runner compiles its jitted segment
  exactly once across identical segments AND across a checkpoint resume,
  with the carry donated wherever the backend supports donation.
  ``audit_compile_once``.

``repro.analysis.lint.run_suite(spec)`` applies the suite to one
``repro.api.ExperimentSpec``; ``python -m repro.analysis.lint`` sweeps the
whole sampler registry x oracle/deployable x compiled/reference and exits
nonzero on any finding.  The lint names below are re-exported lazily (PEP
562) so importing the cost models never drags in jax tracing machinery.
"""
from repro.analysis.hlo import DTYPE_BYTES, UnknownDtypeError, analyze_hlo, dtype_bytes
from repro.analysis.roofline import HW, RooflineTerms, model_flops, roofline

_LINT_EXPORTS = (
    "Finding",
    "LintReport",
    "audit_width",
    "audit_width_hlo",
    "audit_scan_safety",
    "audit_dtypes",
    "audit_compile_once",
    "run_suite",
    "sweep_registry",
)

__all__ = [
    "analyze_hlo",
    "DTYPE_BYTES",
    "dtype_bytes",
    "UnknownDtypeError",
    "HW",
    "RooflineTerms",
    "model_flops",
    "roofline",
    "lint",
    *_LINT_EXPORTS,
]


def __getattr__(name):
    if name in _LINT_EXPORTS or name == "lint":
        import repro.analysis.lint as _lint

        return _lint if name == "lint" else getattr(_lint, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
