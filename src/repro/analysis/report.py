"""Assemble EXPERIMENTS.md roofline/dry-run tables from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.analysis.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.analysis.roofline import HW, roofline

__all__ = ["load_results", "roofline_table", "dryrun_table"]


def load_results(ddir: str) -> list[dict]:
    out = []
    for f in sorted(os.listdir(ddir)):
        if f.endswith(".json"):
            out.append(json.load(open(os.path.join(ddir, f))))
    return out


def _fmt_seconds(x: float) -> str:
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x:.4f}"


def _fmt_bytes(x: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024 or unit == "TB":
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}TB"


def roofline_table(results: list[dict], hw: HW = HW()) -> str:
    """Single-pod roofline table (EXPERIMENTS.md section Roofline)."""
    lines = [
        "| arch | shape | kind | compute s | memory s | collective s | dominant | "
        "flops/dev | HBM/dev | coll/dev | 6ND/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("status") != "ok" or r.get("multi_pod"):
            continue
        t = roofline(
            r["flops"], r["bytes_accessed"], r["collective_bytes"],
            r["n_chips"], r["model_flops"], hw,
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {_fmt_seconds(t.compute_s)} | {_fmt_seconds(t.memory_s)} "
            f"| {_fmt_seconds(t.collective_s)} | **{t.dominant}** "
            f"| {r['flops']:.2e} | {_fmt_bytes(r['bytes_accessed'])} "
            f"| {_fmt_bytes(r['collective_bytes'])} | {t.useful_ratio:.3f} |"
        )
    return "\n".join(lines)


def dryrun_table(results: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | kind | mode | bytes/dev (args+tmp) | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "ok":
            mem = r["memory"]
            per_dev = mem["argument_size_bytes"] + mem["temp_size_bytes"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok | {r['kind']} "
                f"| {r['round_mode'] if r['kind']=='train' else '-'} "
                f"| {_fmt_bytes(per_dev)} | {r['compile_s']} |"
            )
        else:
            reason = r.get("reason", r.get("status"))
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | {r['status']} | - | - | {reason} | - |"
            )
    return "\n".join(lines)


def summarize(results):
    ok = sum(1 for r in results if r.get("status") == "ok")
    skip = sum(1 for r in results if r.get("status") == "skip")
    bad = [r for r in results if r.get("status") not in ("ok", "skip")]
    return ok, skip, bad


def perf_table(perf_dir: str, hw: HW = HW()) -> str:
    """Optimized-variant measurements (EXPERIMENTS.md section Perf)."""
    if not os.path.isdir(perf_dir):
        return "(no results/perf directory)"
    lines = [
        "| variant | opts | compute s | memory s | collective s | dominant |",
        "|---|---|---|---|---|---|",
    ]
    for f in sorted(os.listdir(perf_dir)):
        if not f.endswith(".json"):
            continue
        path = os.path.join(perf_dir, f)
        if os.path.getsize(path) == 0:
            continue
        r = json.load(open(path))
        if r.get("status") != "ok":
            continue
        t = roofline(
            r["flops"], r["bytes_accessed"], r["collective_bytes"],
            r["n_chips"], r["model_flops"], hw,
        )
        lines.append(
            f"| {f[:-5]} | {','.join(r.get('opts', [])) or 'baseline'} "
            f"| {_fmt_seconds(t.compute_s)} | {_fmt_seconds(t.memory_s)} "
            f"| {_fmt_seconds(t.collective_s)} | **{t.dominant}** |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--perf-dir", default="results/perf")
    args = ap.parse_args()
    results = load_results(args.dir)
    ok, skip, bad = summarize(results)
    print(f"## Dry-run ({ok} ok, {skip} skip, {len(bad)} failed)\n")
    print(dryrun_table(results))
    print("\n## Roofline (single-pod 16x16, per-round)\n")
    print(roofline_table(results))
    print("\n## Perf variants (hillclimbed pairs + generalization probes)\n")
    print(perf_table(args.perf_dir))
    if bad:
        print("\nFAILED COMBOS:")
        for r in bad:
            print(" -", r["arch"], r["shape"], "mp" if r.get("multi_pod") else "sp", r.get("status"))


if __name__ == "__main__":
    main()
