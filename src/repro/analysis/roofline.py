"""Three-term roofline model from compiled dry-run artifacts (TPU v5e).

  compute    = HLO_FLOPs    / (chips x 197e12 FLOP/s bf16)
  memory     = HLO_bytes    / (chips x 819e9  B/s HBM)
  collective = coll_bytes   / (chips x 50e9   B/s per ICI link)

``compiled.cost_analysis()`` reports the post-SPMD per-device module; we
normalize everything to PER-DEVICE quantities (flops/bytes from
cost_analysis are already per-device; collective bytes parsed from the
per-device HLO likewise), so the formulas divide by ONE chip's peak.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D uses only *active* params for
MoE; the ratio MODEL_FLOPS / (HLO_FLOPs x chips) exposes remat/redundancy
waste.
"""
from __future__ import annotations

import dataclasses

__all__ = ["HW", "RooflineTerms", "roofline", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_total: float
    useful_ratio: float  # MODEL_FLOPS / (HLO flops x chips)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        return d


def roofline(
    flops_per_device: float,
    bytes_per_device: float,
    coll_bytes_per_device: float,
    n_chips: int,
    model_flops_total: float,
    hw: HW = HW(),
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / hw.peak_flops,
        memory_s=bytes_per_device / hw.hbm_bw,
        collective_s=coll_bytes_per_device / hw.ici_bw,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes_per_device,
        model_flops_total=model_flops_total,
        useful_ratio=(
            model_flops_total / (flops_per_device * n_chips)
            if flops_per_device
            else 0.0
        ),
    )


def active_params(cfg, param_shapes) -> float:
    """Active parameter count, exactly from the parameter tree: every leaf
    counts fully except MoE expert stacks, which count scaled by top_k/E."""
    import jax

    expert_names = {"w_gate", "w_up", "w_down"}
    total = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(param_shapes):
        name = ""
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        size = 1
        for s in leaf.shape:
            size *= int(s)
        if name in expert_names and cfg.n_experts:
            size *= cfg.top_k / cfg.n_experts
        total += size
    return total


def model_flops(n_active: float, tokens_processed: float, kind: str) -> float:
    """6·N_active·D for training, 2·N_active·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens_processed
