"""Trace-invariant lint suite: static analysis of jaxprs and post-SPMD HLO.

The performance story of this repo rests on *structural* properties of the
traced program, not on anything a unit test of outputs can see:

* **width** — the deployable round body aggregates at cohort width: no
  floating-point intermediate scales as O(N*D) (client count x parameter
  dimension).  The legitimate N-sized tensors are (N,)-vectors (sampler
  probabilities, feedback, weights) and integer key/index material.
* **scan-safety** — every registered ``Sampler``'s ``probabilities`` /
  ``sample_from`` / ``update`` traces abstractly (no data-dependent Python
  control flow), contains no host callbacks, has static shapes, and
  ``update`` preserves the state's avals exactly (the scan-carry contract).
* **dtype** — no silent float64/complex128 promotion anywhere in the traced
  graph, and no weak-typed outputs (weak types are erased by checkpoint
  round trips, changing carry avals and forcing recompiles on resume).
* **compile-once** — the segmented runner compiles its segment function
  exactly once across segment boundaries AND across a checkpoint resume
  (numpy round trip of the carry), and the carry is donated on backends
  that support donation.

Until this module existed those invariants were enforced by string-matching
``str(jax.make_jaxpr(...))`` probes — which pass *vacuously* the moment
jaxpr pretty-printing changes.  The auditors here walk the jaxpr equation
graph (recursing into scan/pjit/cond/... sub-jaxprs) and the parsed post-SPMD
HLO (reusing ``repro.analysis.hlo``'s parser), and report typed ``Finding``s
with op, shape, and source provenance.

Entry points
------------

* ``run_suite(spec)`` — lint one ``repro.api.ExperimentSpec``: the sampler's
  scan-safety, the round body's dtype hygiene, width on the deployable /
  pod-scale bodies, the compile-once guard on the segmented runner, and
  (optionally) the width audit repeated on the compiled HLO.
* ``sweep_registry()`` — the full matrix: every registered sampler x
  oracle/deployable x compiled/reference.
* ``python -m repro.analysis.lint`` — CLI over ``sweep_registry`` (or
  ``--spec file.json`` for one spec); exits nonzero on any finding.

All auditors are pure functions jaxpr/HLO-text -> findings so tests can
feed them deliberately-broken programs (an O(N*D) body, a callback-bearing
sampler, an f64 leak) and pin the exact finding each produces.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Finding",
    "LintReport",
    "CALLBACK_PRIMITIVES",
    "iter_eqns",
    "audit_width",
    "audit_width_hlo",
    "audit_replicated_clients",
    "audit_scan_safety",
    "audit_dtypes",
    "audit_compile_once",
    "run_suite",
    "sweep_registry",
    "main",
]

# Host-callback primitives: a scan body containing one forces a device->host
# round trip per iteration (and io/debug callbacks are ordered side effects),
# which breaks the whole-horizon-on-device execution model.
CALLBACK_PRIMITIVES = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"}
)

# Dtypes whose appearance anywhere in a traced graph is a silent promotion
# bug on this repo's f32 substrate (checked by audit_dtypes).
_WIDE_DTYPES = frozenset({"float64", "complex128"})

# Float dtypes in HLO shape syntax (audit_width_hlo); integer/pred buffers
# (keys, indices, masks) are legitimately N-sized and cheap.
_HLO_FLOAT_DTYPES = frozenset(
    {"f64", "f32", "f16", "bf16", "f8e4m3fn", "f8e5m2", "c64", "c128"}
)


# ---------------------------------------------------------------------------
# Findings and reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: which check, where, and the offending op.

    check:      "width" | "scan_safety" | "dtype" | "compile_once"
    target:     what was linted ("round_body[deployable]", "sampler:kvib.update")
    message:    one-sentence statement of the defect
    op:         offending primitive / HLO op name ("" when not op-shaped)
    shape:      offending aval, e.g. "f32[12,60,10]" ("" when not shape-shaped)
    provenance: source location / computation path of the offending equation
    count:      occurrences aggregated into this finding (>= 1)
    """

    check: str
    target: str
    message: str
    op: str = ""
    shape: str = ""
    provenance: str = ""
    count: int = 1

    def render(self) -> str:
        loc = f"  [{self.provenance}]" if self.provenance else ""
        opshape = " ".join(x for x in (self.op, self.shape) if x)
        mult = f" x{self.count}" if self.count > 1 else ""
        head = f"{self.check:<12} {self.target}: "
        return head + (f"{opshape}{mult} — " if opshape else "") + self.message + loc


@dataclasses.dataclass
class LintReport:
    """Findings plus the list of checks that actually ran.

    ``checked`` is what makes a clean report meaningful: an empty findings
    list only certifies the invariants named there."""

    findings: list = dataclasses.field(default_factory=list)
    checked: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def add(self, findings: Iterable[Finding], checked: str) -> None:
        self.findings.extend(findings)
        self.checked.append(checked)

    def extend(self, other: "LintReport") -> None:
        self.findings.extend(other.findings)
        self.checked.extend(other.checked)

    def render(self) -> str:
        lines = []
        if self.ok:
            lines.append(
                f"lint clean: {len(self.checked)} checks, no findings"
            )
        else:
            lines.append(
                f"lint FAILED: {len(self.findings)} finding(s) "
                f"across {len(self.checked)} checks"
            )
            for f in self.findings:
                lines.append("  " + f.render())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Jaxpr walking
# ---------------------------------------------------------------------------


def _as_jaxpr(jaxpr):
    """Accept a ClosedJaxpr or a raw Jaxpr (duck-typed: no jax.core import)."""
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def _sub_jaxprs(eqn) -> Iterator[Any]:
    """Sub-jaxprs referenced by an equation's params (scan/pjit/cond/while/
    custom_vjp/remat/... — anything that stores a Jaxpr or a sequence of
    them), duck-typed so new higher-order primitives are covered for free."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                yield _as_jaxpr(v)


def iter_eqns(jaxpr, path: tuple = ()) -> Iterator[tuple]:
    """Yield ``(eqn, path)`` for every equation in ``jaxpr`` and all nested
    sub-jaxprs; ``path`` is the tuple of enclosing higher-order primitive
    names (e.g. ``("scan", "pjit")``)."""
    for eqn in _as_jaxpr(jaxpr).eqns:
        yield eqn, path
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, path + (eqn.primitive.name,))


def _source_of(eqn, path: tuple) -> str:
    where = "/".join(path)
    try:
        from jax._src import source_info_util

        src = source_info_util.summarize(eqn.source_info)
    except Exception:
        src = ""
    return "/".join(x for x in (where, src) if x)


def _aval_of(var):
    return getattr(var, "aval", None)


def _dtype_name(dtype) -> str:
    """Printable dtype name; extended dtypes (typed PRNG keys, ``key<fry>``)
    have no numpy equivalent, so fall back to their string form."""
    try:
        return np.dtype(dtype).name
    except TypeError:
        return str(dtype)


def _shape_str(aval) -> str:
    return f"{_dtype_name(aval.dtype)}[{','.join(str(d) for d in aval.shape)}]"


def _is_float(aval) -> bool:
    try:
        return jnp.issubdtype(aval.dtype, jnp.floating) or jnp.issubdtype(
            aval.dtype, jnp.complexfloating
        )
    except TypeError:  # extended dtypes (typed PRNG keys) are never float
        return False


# ---------------------------------------------------------------------------
# Pass 1: width auditor (jaxpr)
# ---------------------------------------------------------------------------


def _offends_width(aval, n: int, allow: frozenset) -> bool:
    """An O(N*D) intermediate: a floating array with a client-count axis AND
    more than one element per client.  (N,)-vectors (probabilities, feedback,
    weights) pass; integer key/index material passes (not float)."""
    if aval is None or not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return False
    shape = tuple(aval.shape)
    if shape in allow or not shape:
        return False
    if not all(isinstance(d, int) for d in shape):
        return True  # dynamic shapes violate the static-shape contract anyway
    if n not in shape:
        return False
    if int(np.prod(shape, dtype=np.int64)) <= n:
        return False
    return _is_float(aval)


def audit_width(
    jaxpr,
    n: int,
    *,
    target: str = "",
    allow: Iterable[tuple] = (),
) -> list:
    """Prove no floating intermediate scales as O(N*D) for client count ``n``.

    Walks every equation (sub-jaxprs included) and flags equations that
    *introduce* an offending array — an output with an ``n``-sized axis and
    more than one element per client, where no input already offends (so a
    single leaked buffer yields one finding at its origin, not one per
    downstream consumer).  Findings are aggregated per (op, shape).

    ``allow`` lists exact shape tuples to permit (e.g. a deliberate
    diagnostic buffer).  Pick ``n`` distinctive (not colliding with model or
    batch dimensions) when building lint fixtures — the auditor cannot tell a
    client axis from an accidental equal-sized one.

    Baked-in N-sized *data* (jaxpr constvars — e.g. the federated dataset
    itself, or an N-wide array handed in as a body input) is not an
    intermediate and does not suppress: the first equation that reads it
    into an N-wide float buffer is the origin and gets the finding.
    """
    allow = frozenset(tuple(s) for s in allow)
    # constvars at every nesting level, plus the top-level inputs, are data —
    # exempt from both flagging and origin-suppression.
    exempt = set()
    top = _as_jaxpr(jaxpr)
    exempt.update(id(v) for v in getattr(top, "constvars", ()))
    exempt.update(id(v) for v in top.invars)
    for eqn, _path in iter_eqns(jaxpr):
        for sub in _sub_jaxprs(eqn):
            exempt.update(id(v) for v in getattr(sub, "constvars", ()))

    grouped: dict = {}
    for eqn, path in iter_eqns(jaxpr):
        if any(
            id(v) not in exempt and _offends_width(_aval_of(v), n, allow)
            for v in eqn.invars
        ):
            continue  # propagation of an already-reported buffer
        for var in eqn.outvars:
            aval = _aval_of(var)
            if not _offends_width(aval, n, allow):
                continue
            key = (eqn.primitive.name, _shape_str(aval))
            if key in grouped:
                grouped[key] = dataclasses.replace(
                    grouped[key], count=grouped[key].count + 1
                )
            else:
                grouped[key] = Finding(
                    check="width",
                    target=target,
                    message=(
                        f"intermediate scales as O(N*D) with N={n} "
                        "(cohort-width contract: only (N,)-vectors may be "
                        "client-sized)"
                    ),
                    op=eqn.primitive.name,
                    shape=_shape_str(aval),
                    provenance=_source_of(eqn, path),
                )
    return list(grouped.values())


def audit_replicated_clients(
    jaxpr,
    n: int,
    *,
    target: str = "",
    check_nd: bool = True,
    max_unconstrained: int = 80,
    allow: Iterable[tuple] = (),
) -> list:
    """Per-shard width audit for a round body built with a mesh-sharded
    sampler (the million-client contract: nothing replicated scales O(N)
    per device).

    Equations inside ``shard_map`` sub-jaxprs operate on (N/S,)-local blocks
    — that is the sharded solve doing its job — and are exempt.  Outside
    them the audit enforces two rules:

    * ``check_nd``: no equation introduces a replicated O(N*D) float — the
      ``audit_width`` rule re-applied after excluding the shard-local
      subtrees (oracle bodies hold documented (N, D) diagnostics and set
      ``check_nd=False``);
    * the count of replicated (N,)-f32 temporaries that never flow into a
      ``sharding_constraint`` stays at or under ``max_unconstrained``.  The
      documented per-round vector set — probability algebra, draw mask,
      estimator weights, feedback scatter — measures ~70 such equations
      across the whole sampler registry, and the count is a property of the
      PROGRAM, constant in N; the ceiling is a regression tripwire that
      fires when an edit starts materializing extra per-client temporaries
      (e.g. an (N,)-buffer per loop iteration) instead of keeping them
      shard-local.
    """
    allow = frozenset(tuple(s) for s in allow)
    constrained = set()
    for eqn, _path in iter_eqns(jaxpr):
        if eqn.primitive.name == "sharding_constraint":
            constrained.update(id(v) for v in eqn.invars)

    exempt = set()
    top = _as_jaxpr(jaxpr)
    exempt.update(id(v) for v in getattr(top, "constvars", ()))
    exempt.update(id(v) for v in top.invars)

    findings: list = []
    n_unconstrained = 0
    worst: dict = {}
    for eqn, path in iter_eqns(jaxpr):
        if "shard_map" in path or eqn.primitive.name in (
            "sharding_constraint",
            "shard_map",
        ):
            continue
        if check_nd and not any(
            id(v) not in exempt and _offends_width(_aval_of(v), n, allow)
            for v in eqn.invars
        ):
            for var in eqn.outvars:
                aval = _aval_of(var)
                if _offends_width(aval, n, allow):
                    findings.append(
                        Finding(
                            check="replicated_clients",
                            target=target,
                            message=(
                                f"replicated O(N*D) float with N={n} outside "
                                "every shard_map (sharded-sampler contract: "
                                "per-client blocks live shard-local)"
                            ),
                            op=eqn.primitive.name,
                            shape=_shape_str(aval),
                            provenance=_source_of(eqn, path),
                        )
                    )
        for var in eqn.outvars:
            aval = _aval_of(var)
            if (
                aval is not None
                and hasattr(aval, "shape")
                and tuple(aval.shape) == (n,)
                and _is_float(aval)
                and id(var) not in constrained
            ):
                n_unconstrained += 1
                worst[eqn.primitive.name] = worst.get(eqn.primitive.name, 0) + 1
    if n_unconstrained > max_unconstrained:
        top_ops = ", ".join(
            f"{op} x{c}"
            for op, c in sorted(worst.items(), key=lambda kv: -kv[1])[:5]
        )
        findings.append(
            Finding(
                check="replicated_clients",
                target=target,
                message=(
                    f"{n_unconstrained} replicated (N,)-float temporaries "
                    f"never reach a sharding_constraint (ceiling "
                    f"{max_unconstrained}; top ops: {top_ops}) — the round "
                    "body is growing per-client material beyond the "
                    "documented sampler-state set"
                ),
                op="*",
                shape=f"f32[{n}]",
            )
        )
    return findings


# ---------------------------------------------------------------------------
# Pass 1b: width auditor (post-SPMD HLO text)
# ---------------------------------------------------------------------------


def audit_width_hlo(hlo_text: str, n: int, *, target: str = "") -> list:
    """The width audit repeated on compiled (post-optimization, post-SPMD)
    HLO text — what XLA will actually materialize, after fusion has had its
    say.  Reuses ``repro.analysis.hlo``'s computation parser.

    Same origin filtering as :func:`audit_width`: ops whose operands already
    carry an offending shape are propagation, not origins; ``parameter`` ops
    are the caller's problem (the call edge is walked too)."""
    from repro.analysis import hlo as hlo_mod

    def offends(type_str: str) -> bool:
        for dtype, dims in hlo_mod._SHAPE_RE.findall(type_str):
            if dtype not in _HLO_FLOAT_DTYPES or not dims:
                continue
            shape = [int(d) for d in dims.split(",")]
            if n in shape and int(np.prod(shape, dtype=np.int64)) > n:
                return True
        return False

    grouped: dict = {}
    comps = hlo_mod._parse(hlo_text)
    for cname, ops in comps.items():
        symtab = {op.name: op.rtype for op in ops}
        for op in ops:
            if op.opname in ("parameter", "get-tuple-element", "tuple"):
                continue  # plumbing: the producer is flagged where it lives
            if op.opname == "constant":
                continue  # baked-in input data (the dataset), not an intermediate
            if not offends(op.rtype):
                continue
            paren = op.rest[op.rest.find("(") + 1 :]
            operand_types = [
                symtab.get(name, "")
                for name in hlo_mod._OPERAND_RE.findall(paren.split(")")[0])
            ]
            if any(offends(t) for t in operand_types):
                continue  # propagation
            key = (op.opname, op.rtype.strip())
            if key in grouped:
                grouped[key] = dataclasses.replace(
                    grouped[key], count=grouped[key].count + 1
                )
            else:
                grouped[key] = Finding(
                    check="width",
                    target=target,
                    message=(
                        f"HLO op materializes an O(N*D) buffer with N={n} "
                        "after XLA optimization"
                    ),
                    op=op.opname,
                    shape=op.rtype.strip().split(" ")[0],
                    provenance=f"{cname}/%{op.name}",
                )
    return list(grouped.values())


# ---------------------------------------------------------------------------
# Pass 2: sampler scan-safety
# ---------------------------------------------------------------------------


def _leaf_sig(leaf) -> tuple:
    return (
        tuple(leaf.shape),
        np.dtype(leaf.dtype).name,
        bool(getattr(leaf, "weak_type", False)),
    )


def audit_scan_safety(sampler, *, target: str = "") -> list:
    """Abstractly trace a ``Sampler``'s scan-facing methods and reject
    everything that cannot ride a ``lax.scan`` carry.

    Per method in ``Sampler.scan_safe_methods`` (``probabilities`` /
    ``sample_from`` / ``update``), traced with ``ShapeDtypeStruct`` arguments
    (never concrete values — concrete tracing would silently *succeed* on
    data-dependent Python branches):

    * a ``ConcretizationTypeError`` (bool/int/array conversion of a tracer)
      is surfaced as a data-dependent-control-flow finding;
    * any other trace failure is a finding (the method cannot be staged out);
    * host callbacks (``pure_callback`` / ``io_callback`` /
      ``debug_callback``) anywhere in the jaxpr are findings;
    * non-static output shapes are findings;
    * ``probabilities`` must return a float ``(n,)`` vector;
    * ``update`` must preserve the state pytree's structure and every leaf's
      (shape, dtype, weak_type) exactly — aval drift would fail the scan
      carry on round 2, but only at trace time of some downstream caller;
      here it is caught at the sampler.
    """
    name = target or f"sampler:{type(sampler).__name__}"
    n = sampler.n
    f32 = jnp.float32
    state_sds = sampler.abstract_state()
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    probs_sds = jax.ShapeDtypeStruct((n,), f32)
    draw_sds = sampler.abstract_draw()
    fb_sds = jax.ShapeDtypeStruct((n,), f32)

    cases = {
        "probabilities": (sampler.probabilities, (state_sds,)),
        "sample_from": (sampler.sample_from, (probs_sds, key_sds)),
        "update": (sampler.update, (state_sds, draw_sds, fb_sds)),
    }
    findings: list = []
    for mname in sampler.scan_safe_methods:
        fn, args = cases[mname]
        mtarget = f"{name}.{mname}"
        try:
            closed = jax.make_jaxpr(fn)(*args)
        except jax.errors.ConcretizationTypeError as e:
            findings.append(
                Finding(
                    check="scan_safety",
                    target=mtarget,
                    message=(
                        "data-dependent Python control flow: "
                        + str(e).splitlines()[0]
                    ),
                )
            )
            continue
        except Exception as e:  # noqa: BLE001 — any trace failure is a finding
            findings.append(
                Finding(
                    check="scan_safety",
                    target=mtarget,
                    message=f"abstract trace failed: {type(e).__name__}: "
                    + str(e).splitlines()[0],
                )
            )
            continue

        for eqn, path in iter_eqns(closed):
            if eqn.primitive.name in CALLBACK_PRIMITIVES:
                findings.append(
                    Finding(
                        check="scan_safety",
                        target=mtarget,
                        message="host callback inside a scan-carried method "
                        "(one device->host round trip per round)",
                        op=eqn.primitive.name,
                        provenance=_source_of(eqn, path),
                    )
                )
            for var in eqn.outvars:
                aval = _aval_of(var)
                if aval is not None and hasattr(aval, "shape") and not all(
                    isinstance(d, int) for d in aval.shape
                ):
                    findings.append(
                        Finding(
                            check="scan_safety",
                            target=mtarget,
                            message="non-static shape in traced method",
                            op=eqn.primitive.name,
                            shape=str(aval.shape),
                            provenance=_source_of(eqn, path),
                        )
                    )

        out_sds = jax.eval_shape(fn, *args)
        if mname == "probabilities":
            leaves = jax.tree_util.tree_leaves(out_sds)
            if (
                len(leaves) != 1
                or tuple(leaves[0].shape) != (n,)
                or not jnp.issubdtype(leaves[0].dtype, jnp.floating)
            ):
                findings.append(
                    Finding(
                        check="scan_safety",
                        target=mtarget,
                        message=f"probabilities must return one float (n={n},) "
                        f"vector, got {jax.tree_util.tree_map(_shape_str, out_sds)}",
                    )
                )
        if mname == "update":
            in_tree = jax.tree_util.tree_structure(state_sds)
            out_tree = jax.tree_util.tree_structure(out_sds)
            if in_tree != out_tree:
                findings.append(
                    Finding(
                        check="scan_safety",
                        target=mtarget,
                        message="update() changes the state treedef — the "
                        "scan carry requires a fixed structure",
                    )
                )
            else:
                in_leaves = jax.tree_util.tree_leaves(state_sds)
                out_leaves = jax.tree_util.tree_leaves(out_sds)
                for i, (a, b) in enumerate(zip(in_leaves, out_leaves)):
                    if _leaf_sig(a) != _leaf_sig(b):
                        findings.append(
                            Finding(
                                check="scan_safety",
                                target=mtarget,
                                message=(
                                    f"update() drifts state leaf {i}: "
                                    f"{_leaf_sig(a)} -> {_leaf_sig(b)} — the "
                                    "scan carry requires stable avals "
                                    "(shape, dtype, weak_type)"
                                ),
                            )
                        )
    return findings


# ---------------------------------------------------------------------------
# Pass 3: dtype auditor
# ---------------------------------------------------------------------------


def audit_dtypes(jaxpr, *, target: str = "") -> list:
    """Flag silent f64/weak-type promotion in a traced graph.

    * Any equation that *introduces* a float64/complex128 array (output wide,
      no input wide) is a finding at the promotion point — downstream ops
      merely consuming the wide value are not re-reported, so one leak yields
      one finding.
    * Any weak-typed floating *output* of the jaxpr is a finding: weak types
      do not survive checkpoint round trips (numpy has no weak scalars), so a
      weak carry leaf means resume-time aval drift and recompilation.
    """
    findings: list = []
    closed = _as_jaxpr(jaxpr)

    def wide(var) -> bool:
        aval = _aval_of(var)
        return (
            aval is not None
            and hasattr(aval, "dtype")
            and _dtype_name(aval.dtype) in _WIDE_DTYPES
        )

    for i, var in enumerate(getattr(closed, "constvars", ())):
        if wide(var):
            findings.append(
                Finding(
                    check="dtype",
                    target=target,
                    message=f"constvar {i} bakes 64-bit data into the graph",
                    shape=_shape_str(var.aval),
                )
            )

    seen: dict = {}
    for eqn, path in iter_eqns(jaxpr):
        if any(wide(v) for v in eqn.invars):
            continue  # propagation; the introduction site was flagged
        for var in eqn.outvars:
            if not wide(var):
                continue
            key = (eqn.primitive.name, _shape_str(var.aval))
            if key in seen:
                seen[key] = dataclasses.replace(seen[key], count=seen[key].count + 1)
            else:
                seen[key] = Finding(
                    check="dtype",
                    target=target,
                    message="silent 64-bit promotion (f64/c128 introduced "
                    "into an f32 graph)",
                    op=eqn.primitive.name,
                    shape=_shape_str(var.aval),
                    provenance=_source_of(eqn, path),
                )
    findings.extend(seen.values())

    for i, var in enumerate(closed.outvars):
        aval = _aval_of(var)
        if (
            aval is not None
            and hasattr(aval, "dtype")
            and getattr(aval, "weak_type", False)
            and _is_float(aval)
        ):
            findings.append(
                Finding(
                    check="dtype",
                    target=target,
                    message=f"output {i} is weak-typed — weak types are "
                    "erased by checkpoint round trips, changing the carry "
                    "avals on resume",
                    shape=_shape_str(aval),
                )
            )
    return findings


# ---------------------------------------------------------------------------
# Pass 4: compile-once guard
# ---------------------------------------------------------------------------


def audit_compile_once(
    segment_fn,
    init_state,
    n_rounds: int,
    *,
    n_segments: int = 2,
    resume: bool = True,
    target: str = "",
) -> list:
    """Assert the segmented runner's jit entry point compiles exactly once.

    Static part: ``segment_fn`` built by ``fed.state.make_segment_fn``
    carries lint handles (``segment_fn._lint``) declaring its donation
    setup; the carry must be donated whenever the backend supports donation
    (everything but CPU) and the builder asked for it.

    Dynamic part: runs ``n_segments`` identical-length segments through the
    jit cache counter and verifies the cache grows by exactly one entry;
    then (``resume=True``) round-trips the carry through numpy — exactly the
    transport a ``CheckpointManager`` save/restore applies — and runs one
    more segment, verifying NO new compilation.  A recompile here means some
    carry leaf's aval is not stable under checkpointing (weak types, dtype
    drift, non-canonical shardings) and every resume would pay a full
    compile.

    The probe executes ``(n_segments + 1) * n_rounds`` real rounds, so
    callers hand it a reduced-horizon build (see ``run_suite``).
    """
    name = target or "segment_runner"
    findings: list = []
    info = getattr(segment_fn, "_lint", None)
    backend = jax.default_backend()
    if info is None:
        findings.append(
            Finding(
                check="compile_once",
                target=name,
                message="segment fn carries no lint handles — not built via "
                "fed.state.make_segment_fn, so donation cannot be verified",
            )
        )
        donating = False
    else:
        expected = (0,) if info["donate"] and backend != "cpu" else ()
        if tuple(info["donate_argnums"]) != expected:
            findings.append(
                Finding(
                    check="compile_once",
                    target=name,
                    message=(
                        f"carry donation mismatch on backend {backend!r}: "
                        f"declared donate_argnums={info['donate_argnums']}, "
                        f"expected {expected} — an undonated carry doubles "
                        "peak state memory per segment"
                    ),
                )
            )
        donating = expected != ()

    if not hasattr(segment_fn, "_cache_size"):
        findings.append(
            Finding(
                check="compile_once",
                target=name,
                message="segment fn exposes no jit cache counter "
                "(_cache_size); compile-once cannot be verified",
            )
        )
        return findings

    def call(state):
        arg = jax.tree_util.tree_map(jnp.copy, state) if donating else state
        return segment_fn(arg, n_rounds)

    before = segment_fn._cache_size()
    state = init_state
    for _ in range(n_segments):
        state = call(state)
    grew = segment_fn._cache_size() - before
    if grew != 1:
        findings.append(
            Finding(
                check="compile_once",
                target=name,
                message=f"{grew} compilations across {n_segments} identical "
                f"{n_rounds}-round segments (expected exactly 1)",
            )
        )
    if resume:
        # The numpy round trip IS the checkpoint transport: save_checkpoint
        # writes np arrays, restore feeds them back to the device.
        restored = jax.tree_util.tree_map(
            lambda x: jnp.asarray(np.asarray(x)), state
        )
        mid = segment_fn._cache_size()
        call(restored)
        if segment_fn._cache_size() != mid:
            findings.append(
                Finding(
                    check="compile_once",
                    target=name,
                    message="checkpoint resume recompiles: some carry leaf's "
                    "aval is not stable under the numpy round trip (weak "
                    "type / dtype / sharding drift)",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# The suite: lint one ExperimentSpec
# ---------------------------------------------------------------------------


def _probe_fed_config(cfg, probe_rounds: int, n_segments: int):
    """A reduced-horizon copy of ``cfg`` for the compile-once probe: enough
    rounds for the segments the audit runs (including the resume replay),
    nothing more."""
    return dataclasses.replace(cfg, rounds=probe_rounds * (n_segments + 1))


def run_suite(
    spec,
    *,
    hlo: bool | None = None,
    compile_guard: bool | None = None,
    probe_rounds: int = 2,
) -> LintReport:
    """Lint one ``repro.api.ExperimentSpec`` — the spec front door.

    Passes applied (each recorded in ``LintReport.checked``):

    * scan-safety on the spec's sampler (always);
    * dtype audit on the traced round body (always);
    * width audit on the round body's jaxpr when the body declares the
      cohort-width contract: deployable simulation bodies
      (``oracle_metrics=False`` without ``exact_oracle_equiv``) and every
      pod-scale (zoo) body.  Oracle bodies legitimately hold (N, D) buffers
      (their diagnostics need them) and are not width-audited, as is the
      declared N-width ``exact_oracle_equiv`` escape hatch;
    * compile-once guard on the segmented runner (simulation stack, compiled
      specs; default on — ``compile_guard=False`` skips, ``True`` forces it
      for zoo specs too, where it must first build and compile the full
      model and is therefore off by default);
    * the width audit repeated on post-SPMD compiled HLO (width-audited
      compiled simulation bodies; same defaulting as ``compile_guard``).

    Returns a :class:`LintReport`; ``report.ok`` is the gate.
    """
    from repro import api

    built = api.build(spec)
    report = LintReport()
    sampler_target = f"sampler:{spec.sampler.name}"
    report.add(
        audit_scan_safety(built.sampler, target=sampler_target),
        f"scan_safety:{sampler_target}",
    )

    n = built.dataset.n_clients
    if built.kind == "task":
        from repro.fed import server as fed_server

        cfg = built.fed_config
        mode = "oracle" if cfg.oracle_metrics else (
            "deployable/scatter" if cfg.exact_oracle_equiv else "deployable"
        )
        body_target = f"round_body[{mode}]"
        body, (carry, xs) = fed_server.round_body_for_lint(
            built.task, built.dataset, built.sampler, cfg, None
        )
        closed = jax.make_jaxpr(body)(carry, xs)
        report.add(audit_dtypes(closed, target=body_target), f"dtype:{body_target}")

        width_applies = not cfg.oracle_metrics and not cfg.exact_oracle_equiv
        if width_applies:
            report.add(
                audit_width(closed, n, target=body_target),
                f"width:{body_target}(N={n})",
            )
        if built.sampler.shard is not None:
            report.add(
                audit_replicated_clients(
                    closed, n, target=body_target, check_nd=width_applies
                ),
                f"replicated_clients:{body_target}(N={n})",
            )
        if cfg.compiled and compile_guard is not False:
            probe_cfg = _probe_fed_config(cfg, probe_rounds, 2)
            segment, state = fed_server.build_segment_runner(
                built.task, built.dataset, built.sampler, probe_cfg, None
            )
            seg_target = f"segment_runner[{mode}]"
            report.add(
                audit_compile_once(
                    segment, state, probe_rounds, target=seg_target
                ),
                f"compile_once:{seg_target}",
            )
        if cfg.compiled and width_applies and hlo is not False:
            text = jax.jit(body).lower(carry, xs).compile().as_text()
            report.add(
                audit_width_hlo(text, n, target=f"hlo:{body_target}"),
                f"width_hlo:{body_target}(N={n})",
            )
    else:  # zoo: the pod-scale scan body is always cohort-width
        from repro.fed import round as fed_round

        body_target = f"scan_body[{spec.task.name}]"
        body, (carry, xs) = fed_round.scan_body_for_lint(
            built.arch_config, built.round_spec, built.sampler, built.dataset
        )
        closed = jax.make_jaxpr(body)(carry, xs)
        report.add(audit_dtypes(closed, target=body_target), f"dtype:{body_target}")
        report.add(
            audit_width(closed, n, target=body_target),
            f"width:{body_target}(N={n})",
        )
        if built.sampler.shard is not None:
            report.add(
                audit_replicated_clients(closed, n, target=body_target),
                f"replicated_clients:{body_target}(N={n})",
            )
        if compile_guard is True:
            from repro.fed.round import build_fed_scan_segment
            from repro.models import transformer

            key = jax.random.PRNGKey(spec.execution.seed)
            params = transformer.init_params(built.arch_config, key)
            segment, make_state = build_fed_scan_segment(
                built.arch_config, built.round_spec, built.sampler, built.dataset
            )
            state = make_state(
                params, built.sampler.init(), key, probe_rounds * 3
            )
            seg_target = f"segment_runner[{spec.task.name}]"
            report.add(
                audit_compile_once(
                    segment, state, probe_rounds, target=seg_target
                ),
                f"compile_once:{seg_target}",
            )
        if hlo is True:
            text = jax.jit(body).lower(carry, xs).compile().as_text()
            report.add(
                audit_width_hlo(text, n, target=f"hlo:{body_target}"),
                f"width_hlo:{body_target}(N={n})",
            )
    return report


def _lint_serve_cell(*, fast: bool = False) -> tuple[list, list]:
    """The serve cell: the decode entry point under continuous weight swaps.

    ``audit_dtypes`` walks the decode step's jaxpr on the engine's pinned
    avals (paged caches, traced position/key/temperature); ``audit_compile_
    once`` drives ``ServeEngine.compile_once_probe`` — the decode step with
    a DIFFERENT weight variant installed on every call, i.e. >= 2 hot swaps
    across the audit's segments plus its numpy-round-trip resume — and
    requires the jit cache to grow by exactly one."""
    from repro.configs import get_config
    from repro.models import transformer
    from repro.serve import ServeEngine

    cfg = get_config("smollm-360m").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab=64
    )
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = transformer.init_params(cfg, k1)
    variant = transformer.init_params(cfg, k2)
    engine = ServeEngine(cfg, params, batch=2, max_seq=32, page_size=8)

    findings = list(audit_dtypes(engine.decode_jaxpr(), target="decode step"))
    checked = ["decode step: dtype"]
    if not fast:
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        probe, state = engine.compile_once_probe(prompts, [params, variant])
        findings += audit_compile_once(
            probe, state, 2, target="decode step under weight swaps"
        )
        checked.append("decode step: compile_once across 2 weight swaps")
    return findings, checked


# ---------------------------------------------------------------------------
# The sweep: registry x metric fidelity x execution mode
# ---------------------------------------------------------------------------


def sweep_registry(
    *,
    samplers: Iterable[str] | None = None,
    n_clients: int = 13,
    budget: int = 4,
    rounds: int = 4,
    fast: bool = False,
    progress: Callable[[str], None] | None = None,
) -> LintReport:
    """Lint every registered sampler x oracle/deployable x compiled/reference
    on the canonical simulation task — the CI gate.

    ``n_clients=13`` is deliberately distinctive (prime, unequal to the
    logreg dims 60/10 and the batch size) so the width auditor's client-axis
    detection cannot collide with a model dimension.  ``fast=True`` skips
    the compile-once and HLO passes (pure tracing; seconds instead of
    minutes)."""
    from repro.api import (
        CompressionSpec,
        ExecutionSpec,
        ExperimentSpec,
        FaultSpec,
        FederationSpec,
        SamplerSpec,
        TaskSpec,
    )
    from repro.core.samplers import sampler_names

    # The faulted cell's FaultSpec exercises all three fault axes at once —
    # Markov availability (carried chain), deadline stragglers, and the
    # buffered-async ring (B=3, deliberately != n_clients so the width
    # auditor cannot mistake the (B, D) buffer for a client axis).
    faulted_spec = FaultSpec(
        availability="markov",
        availability_kwargs={"p_on": 0.7, "p_off": 0.2},
        deadline=1.0,
        latency="exponential",
        latency_kwargs={"scale": 0.5},
        async_buffer=3,
        staleness_discount=0.5,
    )

    report = LintReport()
    names = list(samplers) if samplers is not None else sampler_names()
    for name in names:
        kwargs = {"horizon": rounds} if name in ("kvib", "vrb") else {}
        for oracle in (True, False):
            # Beyond (compiled, sampler_axis), the fourth execution cell is
            # the fault-injected compiled path: the availability-composed
            # round body with the deadline and async-ring machinery in the
            # carry must satisfy the same width/dtype/scan-safety/compile-
            # once contracts as the clean body.  The fifth is the compressed
            # path: the int8-quantized (C, D) delta buffer, its fp32
            # per-block scales, and the error-feedback residual in the carry
            # are all intentional narrow/auxiliary arrays that must pass the
            # width and dtype auditors without findings.  Reference x
            # sharded and reference x faulted add nothing the compiled cells
            # don't trace (same bodies), so they are not swept.
            for compiled, axis, faulted, compressed in (
                (True, None, False, False),
                (False, None, False, False),
                (True, "data", False, False),
                (True, None, True, False),
                (True, None, False, True),
            ):
                cell = (
                    f"{name} x {'oracle' if oracle else 'deployable'} x "
                    f"{'compiled' if compiled else 'reference'}"
                    + (" x sharded" if axis else "")
                    + (" x faulted" if faulted else "")
                    + (" x compressed" if compressed else "")
                )
                if progress is not None:
                    progress(cell)
                spec = ExperimentSpec(
                    task=TaskSpec(
                        name="logreg",
                        dataset="synthetic_classification",
                        dataset_kwargs={
                            "n_clients": n_clients,
                            "total": 40 * n_clients,
                            "seed": 0,
                        },
                    ),
                    sampler=SamplerSpec(name=name, kwargs=kwargs),
                    federation=FederationSpec(
                        rounds=rounds, budget=budget, local_steps=1, batch_size=8
                    ),
                    execution=ExecutionSpec(
                        compiled=compiled, oracle_metrics=oracle, sampler_axis=axis
                    ),
                    fault=faulted_spec if faulted else FaultSpec(),
                    compression=CompressionSpec(delta_dtype="int8")
                    if compressed
                    else CompressionSpec(),
                )
                sub = run_suite(
                    spec,
                    hlo=False if fast else None,
                    compile_guard=False if fast else None,
                )
                prefixed = LintReport(
                    findings=[
                        dataclasses.replace(f, target=f"{cell}: {f.target}")
                        for f in sub.findings
                    ],
                    checked=[f"{cell}: {c}" for c in sub.checked],
                )
                report.extend(prefixed)

    # One serve cell alongside the sampler matrix: the train-to-serve decode
    # step (repro.serve.ServeEngine) must satisfy the same dtype and
    # compile-once contracts as the training segment — including across
    # weight hot-swaps, the serving analogue of segment boundaries.
    cell = "serve x paged-decode x swaps"
    if progress is not None:
        progress(cell)
    findings, checked = _lint_serve_cell(fast=fast)
    report.extend(
        LintReport(
            findings=[
                dataclasses.replace(f, target=f"{cell}: {f.target}")
                for f in findings
            ],
            checked=[f"{cell}: {c}" for c in checked],
        )
    )
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Trace-invariant lint: width / scan-safety / dtype / "
        "compile-once static analysis over the sampler registry and both "
        "execution stacks.  Exits nonzero on any finding.",
    )
    ap.add_argument(
        "--spec", default="",
        help="lint ONE ExperimentSpec JSON file instead of the registry sweep",
    )
    ap.add_argument(
        "--samplers", default="",
        help="comma-separated sampler names to sweep (default: whole registry)",
    )
    ap.add_argument("--clients", type=int, default=13)
    ap.add_argument("--budget", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument(
        "--fast", action="store_true",
        help="jaxpr passes only: skip the compile-once guard and the "
        "post-SPMD HLO width audit (no XLA compilation)",
    )
    ap.add_argument(
        "--quiet", action="store_true", help="suppress per-cell progress lines"
    )
    args = ap.parse_args(argv)

    if args.spec:
        from repro.api import ExperimentSpec

        report = run_suite(ExperimentSpec.load(args.spec))
    else:
        progress = None if args.quiet else (lambda cell: print(f"lint {cell} ...", flush=True))
        report = sweep_registry(
            samplers=[s for s in args.samplers.split(",") if s] or None,
            n_clients=args.clients,
            budget=args.budget,
            rounds=args.rounds,
            fast=args.fast,
            progress=progress,
        )
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
