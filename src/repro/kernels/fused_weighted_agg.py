"""Fused ISP-weighted aggregation + feedback norms — the paper's server hot loop.

Algorithm 1 lines 12+14 need, per round, BOTH the global estimate
``d = sum_i (m_i lambda_i / p_i) g_i`` AND the per-client feedback
``pi_i^2 = ||g_i||^2``.  Done naively that is two full HBM passes over the
stacked client updates (the largest tensor the server touches).  This kernel
produces both in ONE pass:

  grid = (n_chunks,)                 chunks over the flattened param dim
  g block   (C, BD)  VMEM            stacked client-update chunk
  w block   (C, 1)   VMEM            estimator weights (m lambda / p)
  d out     (1, BD)                  weighted aggregate chunk
  sq scratch (C, 128) f32            per-client partial squared norms,
                                     accumulated across chunks, emitted last

Oracle: ref.weighted_agg_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fused_weighted_agg",
    "fused_multi_weighted_agg",
    "fused_cohort_agg_and_error",
    "quantize_stacked",
    "dequantize_stacked",
    "dequant_cohort_agg_reference",
    "fused_dequant_cohort_agg",
]

# Saturation point of each supported delta width: int8 symmetric round-to-
# nearest keeps +-127 (the -128 code is unused so the grid is symmetric);
# float8_e4m3fn's largest finite value is 448.
_QMAX = {"int8": 127.0, "fp8": 448.0}


def quant_dtype(name: str):
    """jnp dtype for a delta-width name ('int8' | 'fp8'); raises if the
    installed jax lacks fp8 support."""
    if name == "int8":
        return jnp.int8
    if name == "fp8":
        if not hasattr(jnp, "float8_e4m3fn"):
            raise ValueError("fp8 delta width needs jnp.float8_e4m3fn (jax too old)")
        return jnp.float8_e4m3fn
    raise ValueError(f"unknown delta dtype {name!r}")


def quantize_stacked(flat: jax.Array, *, dtype: str = "int8", scale_block: int = 128):
    """Blockwise symmetric quantization of stacked (C, D) f32 deltas.

    Each slot's flattened delta is split into ``scale_block``-wide blocks with
    one fp32 abs-max scale per (slot, block); D is zero-padded internally to a
    block multiple.  Zero blocks get scale 1.0 (any positive value dequantizes
    them exactly, and 1.0 keeps the scale tensor free of zeros/denormals).

    Returns (q (C, D_pad) int8|fp8, scales (C, nb) f32) with
    ``D_pad = nb * scale_block``.
    """
    c, d = flat.shape
    sb = int(scale_block)
    nb = -(-d // sb)
    d_pad = nb * sb
    flat = flat.astype(jnp.float32)
    if d_pad != d:
        flat = jnp.pad(flat, ((0, 0), (0, d_pad - d)))
    blocks = flat.reshape(c, nb, sb)
    absmax = jnp.max(jnp.abs(blocks), axis=2)
    qmax = _QMAX[dtype]
    scales = jnp.where(absmax > 0.0, absmax / qmax, 1.0).astype(jnp.float32)
    scaled = blocks / scales[:, :, None]
    if dtype == "int8":
        q = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(jnp.int8)
    else:
        q = scaled.astype(quant_dtype(dtype))
    return q.reshape(c, d_pad), scales


def dequantize_stacked(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse of ``quantize_stacked``: (C, D_pad) quantized + (C, nb) scales
    -> (C, D_pad) f32.  Reference/CPU path — the fused kernel below performs
    the same widening per VMEM tile instead."""
    c, d_pad = q.shape
    nb = scales.shape[1]
    sb = d_pad // nb
    blocks = q.astype(jnp.float32).reshape(c, nb, sb) * scales[:, :, None]
    return blocks.reshape(c, d_pad)


def dequant_cohort_agg_reference(
    q: jax.Array, scales: jax.Array, w: jax.Array, lam_c: jax.Array
):
    """Pure-jnp oracle for ``fused_dequant_cohort_agg``: blockwise dequant +
    (2, C) x (C, D_pad) contraction + per-slot squared norms.

    Returns (d (D_pad,) f32, err_sq scalar f32, sq_norms (C,) f32).
    """
    c, d_pad = q.shape
    nb = scales.shape[1]
    sb = d_pad // nb
    blocks = q.astype(jnp.float32).reshape(c, nb, sb) * scales[:, :, None]
    w2 = jnp.stack(
        [w.astype(jnp.float32), w.astype(jnp.float32) - lam_c.astype(jnp.float32)]
    )
    out = jnp.einsum("mc,cbs->mbs", w2, blocks).reshape(2, d_pad)
    sq_norms = jnp.sum(blocks * blocks, axis=(1, 2))
    return out[0], jnp.sum(out[1] ** 2), sq_norms


def _kernel(g_ref, w_ref, d_ref, sq_ref, acc_ref, *, n_chunks):
    ic = pl.program_id(0)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)  # (C, BD)
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    d_ref[0, ...] = jnp.sum(g * w, axis=0).astype(d_ref.dtype)
    acc_ref[:, 0] += jnp.sum(g * g, axis=1)

    @pl.when(ic == n_chunks - 1)
    def _done():
        sq_ref[...] = acc_ref[:, :1]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_weighted_agg(
    g: jax.Array, w: jax.Array, *, block_d: int = 2048, interpret: bool = False
):
    """g (C, D) stacked flattened client updates; w (C,) weights.

    Returns (d (D,) f32, sq_norms (C,) f32) in a single HBM pass over g.
    """
    c, d = g.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_chunks = d // bd
    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    d_out, sq = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((c, 1), lambda ic: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda ic: (0, ic)),
            pl.BlockSpec((c, 1), lambda ic: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((c, 128), jnp.float32)],
        interpret=interpret,
    )(g, w[:, None])
    return d_out[0], sq[:, 0]


def _multi_kernel(g_ref, w_ref, d_ref):
    g = g_ref[...].astype(jnp.float32)  # (C, BD)
    w = w_ref[...].astype(jnp.float32)  # (M, C)
    d_ref[...] = jnp.dot(w, g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_multi_weighted_agg(
    g: jax.Array, w: jax.Array, *, block_d: int = 2048, interpret: bool = False
):
    """g (C, D) stacked flattened client updates; w (M, C) weight rows.

    Returns (M, D) f32 — M independent weighted aggregates sharing a single
    HBM pass over g.  The compiled server loop uses M=2 (estimator weights +
    estimator-minus-target weights) so the estimate and its squared-error
    diagnostic cost one read of the stacked deltas instead of three.
    """
    c, d = g.shape
    m = w.shape[0]
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_chunks = d // bd
    return pl.pallas_call(
        _multi_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((m, c), lambda ic: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bd), lambda ic: (0, ic)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(g, w)


def _cohort_kernel(g_ref, w2_ref, d_ref, err_ref, acc_ref, *, n_chunks):
    ic = pl.program_id(0)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)  # (C, BD)
    w2 = w2_ref[...].astype(jnp.float32)  # (2, C)
    out = jnp.dot(w2, g, preferred_element_type=jnp.float32)  # (2, BD)
    d_ref[...] = out[:1]
    acc_ref[0, 0] += jnp.sum(out[1] ** 2)

    @pl.when(ic == n_chunks - 1)
    def _done():
        err_ref[...] = acc_ref[:1, :1]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_cohort_agg_and_error(
    g: jax.Array,
    w: jax.Array,
    lam_c: jax.Array,
    *,
    block_d: int = 2048,
    interpret: bool = False,
):
    """Cohort-width (C, D) entry point: estimate + squared-error in ONE pass.

    g (C, D) stacked flattened cohort deltas; w (C,) estimator weights from
    ``fed.cohort.select_cohort`` (zero on padding); lam_c (C,) the objective
    weights gathered at the cohort ids (zero on padding).

    Returns (d (D,) f32, err_sq scalar f32) where ``d = sum_c w_c g_c`` and
    ``err_sq = || sum_c (w_c - lam_c) g_c ||^2`` — the cohort-supported part
    of the estimator error.  Unlike ``fused_multi_weighted_agg`` driven at N
    width, nothing here is (N, D)-shaped: the error row is squared and
    accumulated across chunks in VMEM scratch, so only the (D,) estimate and
    one scalar ever leave the kernel.
    """
    c, d = g.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_chunks = d // bd
    w2 = jnp.stack([w.astype(jnp.float32), w.astype(jnp.float32) - lam_c.astype(jnp.float32)])
    kernel = functools.partial(_cohort_kernel, n_chunks=n_chunks)
    d_out, err = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((2, c), lambda ic: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda ic: (0, ic)),
            pl.BlockSpec((1, 1), lambda ic: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 128), jnp.float32)],
        interpret=interpret,
    )(g, w2)
    return d_out[0], err[0, 0]


def _dequant_cohort_kernel(
    q_ref, s_ref, w2_ref, d_ref, err_ref, sqn_ref, acc_err, acc_sqn, *, n_chunks, sb
):
    ic = pl.program_id(0)

    @pl.when(ic == 0)
    def _init():
        acc_err[...] = jnp.zeros_like(acc_err)
        acc_sqn[...] = jnp.zeros_like(acc_sqn)

    q = q_ref[...].astype(jnp.float32)  # (C, BD) widened in VMEM only
    s = s_ref[...].astype(jnp.float32)  # (C, BD // sb)
    c, bd = q.shape
    g = (q.reshape(c, bd // sb, sb) * s[:, :, None]).reshape(c, bd)
    w2 = w2_ref[...].astype(jnp.float32)  # (2, C)
    out = jnp.dot(w2, g, preferred_element_type=jnp.float32)  # (2, BD)
    d_ref[...] = out[:1]
    acc_err[0, 0] += jnp.sum(out[1] ** 2)
    acc_sqn[:, 0] += jnp.sum(g * g, axis=1)

    @pl.when(ic == n_chunks - 1)
    def _done():
        err_ref[...] = acc_err[:1, :1]
        sqn_ref[...] = acc_sqn[:, :1]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_dequant_cohort_agg(
    q: jax.Array,
    scales: jax.Array,
    w: jax.Array,
    lam_c: jax.Array,
    *,
    block_d: int = 2048,
    interpret: bool = False,
):
    """Compressed-width ``fused_cohort_agg_and_error``: the (C, D_pad) stacked
    cohort buffer stays int8/fp8 in HBM and is widened to f32 one VMEM tile at
    a time, fused with the weighted estimate, the squared-error diagnostic,
    and the per-slot dequantized squared norms — the sampler's feedback signal
    computed from exactly the values the estimator saw.  Nothing (C, D)-shaped
    at f32 ever reaches HBM.

    q (C, D_pad) int8|fp8 from ``quantize_stacked``; scales (C, nb) f32 with
    ``nb = D_pad / scale_block``; w / lam_c as in ``fused_cohort_agg_and_error``.

    Returns (d (D_pad,) f32, err_sq scalar f32, sq_norms (C,) f32).
    """
    c, d_pad = q.shape
    nb = scales.shape[1]
    assert d_pad % nb == 0, (d_pad, nb)
    sb = d_pad // nb
    bd = min(block_d, d_pad)
    assert d_pad % bd == 0 and bd % sb == 0, (d_pad, bd, sb)
    n_chunks = d_pad // bd
    w2 = jnp.stack(
        [w.astype(jnp.float32), w.astype(jnp.float32) - lam_c.astype(jnp.float32)]
    )
    kernel = functools.partial(_dequant_cohort_kernel, n_chunks=n_chunks, sb=sb)
    d_out, err, sqn = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((c, bd // sb), lambda ic: (0, ic)),
            pl.BlockSpec((2, c), lambda ic: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda ic: (0, ic)),
            pl.BlockSpec((1, 1), lambda ic: (0, 0)),
            pl.BlockSpec((c, 1), lambda ic: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 128), jnp.float32),
            pltpu.VMEM((c, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, scales, w2)
    return d_out[0], err[0, 0], sqn[:, 0]
