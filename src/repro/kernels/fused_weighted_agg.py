"""Fused ISP-weighted aggregation + feedback norms — the paper's server hot loop.

Algorithm 1 lines 12+14 need, per round, BOTH the global estimate
``d = sum_i (m_i lambda_i / p_i) g_i`` AND the per-client feedback
``pi_i^2 = ||g_i||^2``.  Done naively that is two full HBM passes over the
stacked client updates (the largest tensor the server touches).  This kernel
produces both in ONE pass:

  grid = (n_chunks,)                 chunks over the flattened param dim
  g block   (C, BD)  VMEM            stacked client-update chunk
  w block   (C, 1)   VMEM            estimator weights (m lambda / p)
  d out     (1, BD)                  weighted aggregate chunk
  sq scratch (C, 128) f32            per-client partial squared norms,
                                     accumulated across chunks, emitted last

Oracle: ref.weighted_agg_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fused_weighted_agg",
    "fused_multi_weighted_agg",
    "fused_cohort_agg_and_error",
]


def _kernel(g_ref, w_ref, d_ref, sq_ref, acc_ref, *, n_chunks):
    ic = pl.program_id(0)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)  # (C, BD)
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    d_ref[0, ...] = jnp.sum(g * w, axis=0).astype(d_ref.dtype)
    acc_ref[:, 0] += jnp.sum(g * g, axis=1)

    @pl.when(ic == n_chunks - 1)
    def _done():
        sq_ref[...] = acc_ref[:, :1]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_weighted_agg(
    g: jax.Array, w: jax.Array, *, block_d: int = 2048, interpret: bool = False
):
    """g (C, D) stacked flattened client updates; w (C,) weights.

    Returns (d (D,) f32, sq_norms (C,) f32) in a single HBM pass over g.
    """
    c, d = g.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_chunks = d // bd
    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    d_out, sq = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((c, 1), lambda ic: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda ic: (0, ic)),
            pl.BlockSpec((c, 1), lambda ic: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((c, 128), jnp.float32)],
        interpret=interpret,
    )(g, w[:, None])
    return d_out[0], sq[:, 0]


def _multi_kernel(g_ref, w_ref, d_ref):
    g = g_ref[...].astype(jnp.float32)  # (C, BD)
    w = w_ref[...].astype(jnp.float32)  # (M, C)
    d_ref[...] = jnp.dot(w, g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_multi_weighted_agg(
    g: jax.Array, w: jax.Array, *, block_d: int = 2048, interpret: bool = False
):
    """g (C, D) stacked flattened client updates; w (M, C) weight rows.

    Returns (M, D) f32 — M independent weighted aggregates sharing a single
    HBM pass over g.  The compiled server loop uses M=2 (estimator weights +
    estimator-minus-target weights) so the estimate and its squared-error
    diagnostic cost one read of the stacked deltas instead of three.
    """
    c, d = g.shape
    m = w.shape[0]
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_chunks = d // bd
    return pl.pallas_call(
        _multi_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((m, c), lambda ic: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bd), lambda ic: (0, ic)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(g, w)


def _cohort_kernel(g_ref, w2_ref, d_ref, err_ref, acc_ref, *, n_chunks):
    ic = pl.program_id(0)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)  # (C, BD)
    w2 = w2_ref[...].astype(jnp.float32)  # (2, C)
    out = jnp.dot(w2, g, preferred_element_type=jnp.float32)  # (2, BD)
    d_ref[...] = out[:1]
    acc_ref[0, 0] += jnp.sum(out[1] ** 2)

    @pl.when(ic == n_chunks - 1)
    def _done():
        err_ref[...] = acc_ref[:1, :1]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_cohort_agg_and_error(
    g: jax.Array,
    w: jax.Array,
    lam_c: jax.Array,
    *,
    block_d: int = 2048,
    interpret: bool = False,
):
    """Cohort-width (C, D) entry point: estimate + squared-error in ONE pass.

    g (C, D) stacked flattened cohort deltas; w (C,) estimator weights from
    ``fed.cohort.select_cohort`` (zero on padding); lam_c (C,) the objective
    weights gathered at the cohort ids (zero on padding).

    Returns (d (D,) f32, err_sq scalar f32) where ``d = sum_c w_c g_c`` and
    ``err_sq = || sum_c (w_c - lam_c) g_c ||^2`` — the cohort-supported part
    of the estimator error.  Unlike ``fused_multi_weighted_agg`` driven at N
    width, nothing here is (N, D)-shaped: the error row is squared and
    accumulated across chunks in VMEM scratch, so only the (D,) estimate and
    one scalar ever leave the kernel.
    """
    c, d = g.shape
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    n_chunks = d // bd
    w2 = jnp.stack([w.astype(jnp.float32), w.astype(jnp.float32) - lam_c.astype(jnp.float32)])
    kernel = functools.partial(_cohort_kernel, n_chunks=n_chunks)
    d_out, err = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((c, bd), lambda ic: (0, ic)),
            pl.BlockSpec((2, c), lambda ic: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda ic: (0, ic)),
            pl.BlockSpec((1, 1), lambda ic: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 128), jnp.float32)],
        interpret=interpret,
    )(g, w2)
    return d_out[0], err[0, 0]
