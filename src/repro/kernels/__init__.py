"""Pallas TPU kernels for the compute hot-spots (DESIGN.md section 5).

Each kernel ships three artifacts: the pl.pallas_call implementation with
explicit BlockSpec VMEM tiling (<name>.py), a jit'd wrapper (ops.py), and a
pure-jnp oracle (ref.py).  CPU CI validates with interpret=True.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_weighted_agg import (
    dequantize_stacked,
    fused_cohort_agg_and_error,
    fused_dequant_cohort_agg,
    fused_multi_weighted_agg,
    fused_weighted_agg,
    quantize_stacked,
)
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.sharded_waterfill import waterfill_level_stats
from repro.kernels.ssd_scan import ssd_scan

__all__ = [
    "ops",
    "ref",
    "dequantize_stacked",
    "flash_attention",
    "fused_cohort_agg_and_error",
    "fused_dequant_cohort_agg",
    "fused_multi_weighted_agg",
    "fused_weighted_agg",
    "quantize_stacked",
    "rmsnorm",
    "ssd_scan",
    "waterfill_level_stats",
]
