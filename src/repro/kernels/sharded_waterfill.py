"""Per-shard water-filling threshold statistics as a Pallas segmented scan.

The sharded ISP solve (``repro.core.solver``) finds the scalar water level
``s`` with ``sum_i clip(a_i/s, p_min, 1) = K`` by a fixed-depth threshold
search: every refinement round evaluates the monotone counting function at a
whole ladder of L candidate levels, the per-shard partial statistics are
``psum``-merged across the mesh, and the bracket tightens to the pair of
adjacent levels enclosing the solution.  This kernel is the per-shard
workhorse of that search — one sequential pass over the shard's score chunks
accumulating, for all L levels at once:

  n_below[k] = #{ a_i <  levels[k] }          (searchsorted side='left')
  n_floor[k] = #{ a_i <= floors[k] }          (searchsorted side='right',
                                               floors[k] = levels[k] * p_min)
  mid_sum[k] = sum of a_i with floors[k] < a_i < levels[k]

Same block structure as ``ssd_scan.py``: a sequential chunk grid dimension
with the running (3, L) accumulator carried in VMEM scratch, initialized via
``pl.when`` on the first chunk.  No chunk's scores ever round-trip to HBM
between grid steps.

  grid = (n_chunks,)                 chunks sequential (accumulator carry)
  scores block  (1, Q)    VMEM       one chunk of shard-local scores
  levels block  (2, L)    VMEM       [levels; floors], resident every step
  acc           (3, L) f32 scratch   carried across chunks

Padding contract: score entries equal to +inf are inert (they sit above any
finite level, so no count or sum includes them) — callers pad both the
shard-split remainder and the chunk remainder with +inf.  Counts are carried
as f32, exact for shards up to 2^24 scores.

Oracle: ref.waterfill_stats_reference (order-independent masked reductions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["waterfill_level_stats"]

_LANE = 128


def _kernel(s_ref, lv_ref, out_ref, acc_ref):
    ic = pl.program_id(0)

    @pl.when(ic == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = s_ref[0].astype(jnp.float32)  # (Q,)
    levels = lv_ref[0].astype(jnp.float32)  # (L,)
    floors = lv_ref[1].astype(jnp.float32)  # (L,)

    below = a[:, None] < levels[None, :]  # (Q, L)
    at_floor = a[:, None] <= floors[None, :]
    in_mid = jnp.logical_and(~at_floor, below)

    acc_ref[0, :] = acc_ref[0, :] + jnp.sum(below.astype(jnp.float32), axis=0)
    acc_ref[1, :] = acc_ref[1, :] + jnp.sum(at_floor.astype(jnp.float32), axis=0)
    acc_ref[2, :] = acc_ref[2, :] + jnp.sum(
        jnp.where(in_mid, a[:, None], 0.0), axis=0
    )
    out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def waterfill_level_stats(
    scores: jax.Array,
    levels: jax.Array,
    floors: jax.Array,
    *,
    chunk: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """scores (M,) shard-local (+inf entries inert); levels/floors (L,).

    Returns ``(n_below, n_floor, mid_sum)``, each (L,) f32 — the shard-local
    threshold statistics defined in the module docstring, ready for a psum
    merge across the client-shard mesh axis."""
    (m,) = scores.shape
    (l,) = levels.shape
    q = max(_LANE, min(chunk, -(-m // _LANE) * _LANE))
    m_pad = -(-max(m, 1) // q) * q
    l_pad = -(-l // _LANE) * _LANE
    s2 = jnp.full((m_pad,), jnp.inf, jnp.float32).at[:m].set(
        scores.astype(jnp.float32)
    ).reshape(m_pad // q, q)
    lv2 = jnp.stack(
        [
            jnp.ones((l_pad,), jnp.float32).at[:l].set(levels.astype(jnp.float32)),
            jnp.zeros((l_pad,), jnp.float32).at[:l].set(floors.astype(jnp.float32)),
        ]
    )
    out = pl.pallas_call(
        _kernel,
        grid=(m_pad // q,),
        in_specs=[
            pl.BlockSpec((1, q), lambda ic: (ic, 0)),
            pl.BlockSpec((2, l_pad), lambda ic: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, l_pad), lambda ic: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((3, l_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((3, l_pad), jnp.float32)],
        interpret=interpret,
    )(s2, lv2)
    return out[0, :l], out[1, :l], out[2, :l]
