"""RMSNorm as a Pallas kernel — bandwidth-bound normalization used everywhere.

  grid = (n_row_blocks,)
  x block (BR, D) VMEM -> y block (BR, D)

Oracle: ref.rmsnorm_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm"]


def _kernel(x_ref, s_ref, y_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    scale = s_ref[...].astype(jnp.float32)  # (1, D)
    norm = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    y_ref[...] = (norm * (1.0 + scale)).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array, scale: jax.Array, eps: float = 1e-6, block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x (R, D); scale (D,)."""
    r, d = x.shape
    br = min(block_rows, r)
    assert r % br == 0, (r, br)
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale[None, :])
