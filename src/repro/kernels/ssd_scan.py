"""Mamba2 SSD scan as a Pallas TPU kernel (one head per grid row).

TPU adaptation of the CUDA selective-scan: within each chunk the recurrence
is evaluated as two MXU GEMMs (C·Bᵀ ∘ decay) @ X plus a rank-N state
contribution; across chunks a (hd, N) summary state is carried in VMEM
scratch along the sequential chunk grid dimension.  No token-level
recurrence ever touches HBM.

  grid = (B*H, n_chunks)            chunks sequential (state carry)
  x block   (1, Q, hd)   VMEM       dt-weighted head inputs
  da block  (1, Q, 128)  VMEM       per-step log-decay (lane-padded)
  b/c block (1, Q, N)    VMEM
  state     (hd, N) f32  scratch    carried across chunks

Oracle: ref.ssd_reference (sequential scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, da_ref, b_ref, c_ref, y_ref, state_ref, *, q_len, n_chunks):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (Q, hd)
    da = da_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    b = b_ref[0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0].astype(jnp.float32)  # (Q, N)

    cum = jnp.cumsum(da)  # inclusive cumulative log decay
    # intra-chunk: y[t] = sum_{s<=t} (c_t . b_s) * exp(cum_t - cum_s) * x_s
    seg = cum[:, None] - cum[None, :]  # (Q, Q)
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    )
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    y_intra = jax.lax.dot_general(
        cb * decay, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: y[t] += c_t @ (state^T) * exp(cum_t)
    state = state_ref[...]  # (hd, N)
    y_inter = jax.lax.dot_general(
        c * jnp.exp(cum)[:, None], state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, hd)

    y_ref[0, ...] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(sum da) * S + sum_s exp(cum_last - cum_s) x_s b_s^T
    w = jnp.exp(cum[-1] - cum)  # (Q,)
    state_new = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x * w[:, None], b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (hd, N)
    state_ref[...] = state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    da: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x (BH, S, hd); da (BH, S) log decays; b, c (BH, S, N). Returns y."""
    bh, s, hd = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    n_chunks = s // q
    grid = (bh, n_chunks)
    da_pad = jnp.broadcast_to(da[..., None], (bh, s, 128))

    kernel = functools.partial(_kernel, q_len=q, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, hd), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, q, 128), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, q, n), lambda ih, ic: (ih, ic, 0)),
            pl.BlockSpec((1, q, n), lambda ih, ic: (ih, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, hd), lambda ih, ic: (ih, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
    )(x, da_pad, b, c)
