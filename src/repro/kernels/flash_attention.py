"""Flash attention (forward) as a Pallas TPU kernel.

Blockwise online-softmax over the KV sequence with explicit VMEM tiling:

  grid = (heads, n_q_blocks, n_kv_blocks)   — kv innermost, sequential
  q block    (1, BQ, hd)   VMEM
  k/v block  (1, BK, hd)   VMEM
  scratch    acc (BQ, hd) f32, m/l (BQ, 128) f32 persisted across kv steps

Tile sizes default to MXU-aligned 128 (BQ) x 128 (BK); hd is kept whole
(<=256 for every assigned arch).  Causal and sliding-window masks are applied
from absolute block offsets; soft-capping (gemma2) happens pre-mask.
The kernel is numerically exact w.r.t. `ref.mha_reference` up to dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -2.3819763e38


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, softcap, bq, bk, n_kv,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)  # (BK, hd)
    v = v_ref[0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BQ, BK)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_ref[:, 0]  # (BQ,)
    l_prev = l_ref[:, 0]
    m_cur = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new[:, None])  # (BQ, BK)
    correction = jnp.exp(m_prev - m_new)
    l_new = correction * l_prev + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _done():
        # fully-masked rows (l == 0) produce 0, not NaN
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q,k,v: (H, S, hd) — collapsed batch*heads leading dim. Returns (H,S,hd)."""
    h, s_q, hd = q.shape
    s_k = k.shape[1]
    bq = min(block_q, s_q)
    bk = min(block_k, s_k)
    assert s_q % bq == 0 and s_k % bk == 0, (s_q, s_k, bq, bk)
    n_q, n_kv = s_q // bq, s_k // bk
    grid = (h, n_q, n_kv)

    kernel = functools.partial(
        _kernel,
        scale=hd**-0.5,
        causal=causal,
        window=window,
        softcap=softcap,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h_, iq, ik: (h_, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda h_, iq, ik: (h_, ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda h_, iq, ik: (h_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h_, iq, ik: (h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),  # acc
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (lane-padded)
            pltpu.VMEM((bq, 128), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(q, k, v)
