"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors a kernel in this package 1:1; the test suite sweeps
shapes/dtypes and asserts allclose between kernel (interpret=True on CPU)
and oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "mha_reference",
    "ssd_reference",
    "weighted_agg_reference",
    "rmsnorm_reference",
    "waterfill_stats_reference",
]


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """q,k,v: (H, S, hd) single collapsed batch*head leading dim."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    s_q, s_k = q.shape[1], k.shape[1]
    qpos = jnp.arange(s_q)[:, None]
    kpos = jnp.arange(s_k)[None, :]
    mask = jnp.ones((s_q, s_k), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    logits = jnp.where(mask[None], logits, -2.3819763e38)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def ssd_reference(x, da, b, c):
    """Sequential SSD recurrence (the definitionally-correct scan).

    x  (B, S, hd)  dt-weighted inputs for ONE head
    da (B, S)      per-step log decay (negative)
    b  (B, S, N)   input projections
    c  (B, S, N)   output projections
    Returns y (B, S, hd), final state (B, hd, N).
    """

    def step(h, inp):
        x_t, da_t, b_t, c_t = inp
        h = h * jnp.exp(da_t)[:, None, None] + x_t[..., :, None] * b_t[..., None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    bsz, s, hd = x.shape
    n = b.shape[-1]
    h0 = jnp.zeros((bsz, hd, n), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(da.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def weighted_agg_reference(g: jax.Array, w: jax.Array):
    """g (C, D) stacked client updates; w (C,) estimator weights.

    Returns (d (D,), sq_norms (C,)) — the ISP-weighted aggregate and the
    per-client squared update norms (the K-Vib feedback), both in f32.
    """
    gf = g.astype(jnp.float32)
    d = jnp.einsum("c,cd->d", w.astype(jnp.float32), gf)
    sq = jnp.sum(gf * gf, axis=1)
    return d, sq


def waterfill_stats_reference(scores: jax.Array, levels: jax.Array, floors: jax.Array):
    """scores (M,) (+inf entries inert); levels/floors (L,).

    Returns (n_below, n_floor, mid_sum), each (L,) f32 — per-level threshold
    statistics of the water-filling counting function (order-independent
    masked reductions, the definitionally-correct form):

      n_below[k] = #{ a_i <  levels[k] }
      n_floor[k] = #{ a_i <= floors[k] }
      mid_sum[k] = sum of a_i with floors[k] < a_i < levels[k]
    """
    a = scores.astype(jnp.float32)[:, None]
    lv = levels.astype(jnp.float32)[None, :]
    fl = floors.astype(jnp.float32)[None, :]
    below = a < lv
    at_floor = a <= fl
    in_mid = jnp.logical_and(~at_floor, below)
    return (
        jnp.sum(below.astype(jnp.float32), axis=0),
        jnp.sum(at_floor.astype(jnp.float32), axis=0),
        jnp.sum(jnp.where(in_mid, a, 0.0), axis=0),
    )


def rmsnorm_reference(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
