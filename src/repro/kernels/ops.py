"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels run compiled; on CPU (this container) they run
in ``interpret=True`` mode, which executes the kernel body with JAX ops —
bit-for-bit the same program logic, validated against the ``ref`` oracles by
the test suite.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_weighted_agg import fused_weighted_agg as _agg
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _ssd
from repro.kernels.sharded_waterfill import waterfill_level_stats as _waterfill

__all__ = [
    "flash_attention",
    "ssd_scan",
    "fused_weighted_agg",
    "rmsnorm",
    "aggregate_cohort_updates",
    "waterfill_level_stats",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, **kw):
    return _flash(q, k, v, interpret=_interpret(), **kw)


def ssd_scan(x, da, b, c, **kw):
    return _ssd(x, da, b, c, interpret=_interpret(), **kw)


def fused_weighted_agg(g, w, **kw):
    return _agg(g, w, interpret=_interpret(), **kw)


def rmsnorm(x, scale, **kw):
    return _rmsnorm(x, scale, interpret=_interpret(), **kw)


def waterfill_level_stats(scores, levels, floors, **kw):
    return _waterfill(scores, levels, floors, interpret=_interpret(), **kw)


def aggregate_cohort_updates(stacked_deltas, weights, *, block_d: int = 2048):
    """Pytree-level driver for the fused kernel: flattens a stacked client
    update pytree (leading client axis), runs one fused pass, and returns
    (delta_pytree, sq_norms (C,)).

    This is the deployable server aggregation path (Algorithm 1 lines 12+14
    in one HBM traversal).
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked_deltas)
    c = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(c, -1) for l in leaves], axis=1)
    d_total = flat.shape[1]
    pad = (-d_total) % block_d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    d_flat, sq = fused_weighted_agg(flat, weights, block_d=block_d)
    if pad:
        d_flat = d_flat[:-pad]
    out_leaves = []
    off = 0
    for l in leaves:
        n = int(np_prod(l.shape[1:]))
        out_leaves.append(d_flat[off : off + n].reshape(l.shape[1:]).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out_leaves), sq


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# training-usable flash attention: Pallas forward + analytic recompute bwd
# ---------------------------------------------------------------------------


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_trainable(q, k, v, causal=True, window=None, softcap=None):
    """Flash-attention with a custom VJP: forward runs the Pallas kernel
    (O(S) memory — no S x S probabilities stored); backward recomputes
    attention blockwise from (q, k, v, out) with the standard analytic
    gradient.  This is the kernel the train path uses on TPU; CPU CI
    validates it against jax.grad of the jnp oracle."""
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  interpret=_interpret())


def _fa_fwd(q, k, v, causal, window, softcap):
    out = _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                 interpret=_interpret())
    return out, (q, k, v, out)


def _fa_bwd(causal, window, softcap, res, d_out):
    q, k, v, out = res
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    do = d_out.astype(jnp.float32)
    scale = q.shape[-1] ** -0.5
    s_raw = jnp.einsum("hqd,hkd->hqk", qf, kf) * scale
    if softcap is not None:
        s_capped = softcap * jnp.tanh(s_raw / softcap)
    else:
        s_capped = s_raw
    s_q, s_k = q.shape[1], k.shape[1]
    qpos = jnp.arange(s_q)[:, None]
    kpos = jnp.arange(s_k)[None, :]
    mask = jnp.ones((s_q, s_k), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    logits = jnp.where(mask[None], s_capped, -2.3819763e38)
    p = jax.nn.softmax(logits, axis=-1)
    dv = jnp.einsum("hqk,hqd->hkd", p, do)
    dp = jnp.einsum("hqd,hkd->hqk", do, vf)
    d_rows = jnp.sum(do * out.astype(jnp.float32), axis=-1, keepdims=True)
    ds = p * (dp - d_rows)  # grad wrt (masked, capped) logits
    if softcap is not None:
        ds = ds * (1.0 - jnp.tanh(s_raw / softcap) ** 2)  # through the cap
    ds = jnp.where(mask[None], ds, 0.0)
    dq = jnp.einsum("hqk,hkd->hqd", ds, kf) * scale
    dk = jnp.einsum("hqk,hqd->hkd", ds, qf) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
