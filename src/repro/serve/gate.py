"""Promotion gate: held-out-loss scoring and promote/rollback per boundary.

Every checkpoint boundary the watcher surfaces is scored on a fixed set of
held-out batches before it may touch the engine: ``PromotionGate.consider``
computes the candidate's mean eval loss with one jitted loss program
(params are an argument, so scoring N candidates compiles once) and
promotes iff the candidate is no worse than the best loss served so far
(within ``tolerance``).  A rejected candidate is a *rollback*: the engine
keeps serving the incumbent weights and the decision is recorded either
way in the ``PromotionLog``.

The held-out batches follow the eval-path convention of ``api.run``'s
simulation stack (``FederationSpec.eval_batches`` fixed batches, scored on
a schedule): ``heldout_batches`` draws them from the built experiment's
``FederatedDataset`` with a dedicated eval key stream (``fold_in`` tag off
a fresh seed key) that is disjoint by construction from the training chain
key — the gate never scores on batches the trainer's key stream can emit.

The gate is primed with the *initial* (round-0) params: the serving
process starts on the untrained model, so the first trained boundary
normally clears the bar — "promote when training helped" rather than
"promote never" or "promote always".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer

__all__ = ["PromotionRecord", "PromotionLog", "PromotionGate", "heldout_batches"]


def heldout_batches(dataset, *, n_batches: int, batch_size: int, seed: int = 0):
    """``n_batches`` fixed (tokens, targets) eval batches from ``dataset``.

    Clients and within-client rows are drawn from an eval-only key stream
    (``fold_in(PRNGKey(seed), 7)``); the batches are materialized once and
    reused for every candidate, so gate decisions are comparable across the
    whole run."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 7)
    out = []
    for _ in range(int(n_batches)):
        key, k_client, k_rows = jax.random.split(key, 3)
        client = jax.random.randint(k_client, (), 0, dataset.n_clients)
        out.append(dataset.client_batch(client, k_rows, int(batch_size)))
    return out


@dataclasses.dataclass(frozen=True)
class PromotionRecord:
    """One gate decision: the candidate's step/loss vs. the incumbent."""

    step: int
    loss: float
    best_loss: float  # best served loss BEFORE this decision
    promoted: bool

    @property
    def reason(self) -> str:
        rel = "<=" if self.promoted else ">"
        return f"loss {self.loss:.4f} {rel} best {self.best_loss:.4f}"


class PromotionLog:
    """Append-only record of every promote/rollback decision."""

    def __init__(self):
        self.records: list[PromotionRecord] = []

    def append(self, record: PromotionRecord) -> None:
        self.records.append(record)

    @property
    def promotions(self) -> int:
        return sum(r.promoted for r in self.records)

    @property
    def rollbacks(self) -> int:
        return sum(not r.promoted for r in self.records)

    def render(self) -> str:
        lines = [
            f"step {r.step:>4} {'PROMOTE' if r.promoted else 'ROLLBACK'} "
            f"({r.reason})"
            for r in self.records
        ]
        lines.append(
            f"{self.promotions} promotions, {self.rollbacks} rollbacks"
        )
        return "\n".join(lines)


class PromotionGate:
    """Score candidates on held-out loss; promote iff no worse than served.

    Parameters
    ----------
    cfg:
        The arch config of the served model (the loss program's shape).
    batches:
        Fixed (tokens, targets) held-out batches (``heldout_batches``).
    tolerance:
        Slack on the comparison: promote when
        ``loss <= best_loss + tolerance``.  0.0 = strictly-no-worse.
    """

    def __init__(self, cfg, batches, *, tolerance: float = 0.0):
        if not batches:
            raise ValueError("PromotionGate needs at least one held-out batch")
        self.batches = [
            (jnp.asarray(t, jnp.int32), jnp.asarray(y, jnp.int32))
            for t, y in batches
        ]
        self.tolerance = float(tolerance)
        self.best_loss: float | None = None
        self.log = PromotionLog()
        # Params are an ARGUMENT: one compiled loss program scores every
        # candidate of the run (the gate-side compile-once contract).
        self._loss = jax.jit(
            lambda p, tokens, targets: transformer.loss_fn(p, cfg, (tokens, targets))
        )

    def score(self, params) -> float:
        """Mean held-out loss of ``params`` over the fixed batches."""
        total = 0.0
        for tokens, targets in self.batches:
            total += float(self._loss(params, tokens, targets))
        return total / len(self.batches)

    def prime(self, params) -> float:
        """Set the bar to the currently-served params' loss (round-0 init)."""
        self.best_loss = self.score(params)
        return self.best_loss

    def consider(self, candidate) -> bool:
        """Gate one ``Candidate``: score, decide, record.  True = promote."""
        loss = self.score(candidate.params)
        prev = self.best_loss if self.best_loss is not None else float("inf")
        promoted = loss <= prev + self.tolerance
        self.log.append(
            PromotionRecord(
                step=int(candidate.step),
                loss=loss,
                best_loss=prev,
                promoted=promoted,
            )
        )
        if promoted:
            self.best_loss = loss
        return promoted
