"""repro.serve: the train-to-serve subsystem.

Turns the segmented trainer's checkpoint boundaries into a live serving
loop: a paged-KV-cache decode engine (``engine``), a manifest-following
checkpoint watcher (``swap``), an eval-gated promote/rollback decision
per boundary (``gate``), and the loop composing them under traffic
(``session``).  Front doors: ``repro.launch.serve --follow CKPT_DIR``
(separate process) and ``examples/fed_lm.py --serve`` (in-process
closed loop).

The hand-off contract (what the manifest promises a reader)
-----------------------------------------------------------

The training process (``fed.state.run_segmented`` + ``CheckpointManager``)
and the serving process share nothing but a directory.  The manifest
(``manifest.json``) is the entire coordination protocol:

1. **Commit point.**  A step exists iff the manifest references it.  The
   manager writes checkpoint files first and the manifest last (tmp +
   ``os.replace``), so a reader can never observe a partially written
   step: whatever ``latest()`` / ``wait_for_next()`` returns is fully on
   disk.  (A torn ``.npz`` may exist after a crash — but it is never
   *referenced*.)
2. **Fingerprint match.**  The manifest records
   ``config_fingerprint(spec.to_dict())``; the watcher's manager carries
   the serving process's own fingerprint and ``restore`` refuses a
   mismatch — train and serve provably agree on the full
   ``ExperimentSpec`` (``launch.train`` drops ``spec.json`` next to the
   manifest so the server can reconstruct it).
3. **Treedef check.**  ``restore`` validates the manifest's treedef hash
   against the serving process's restore template
   (``api.restore_template(spec)``), so a restored candidate is
   structurally identical to what the engine's pinned swap signature
   expects — a payload that deserializes is a payload that swaps.

The compile-once weight-swap contract
-------------------------------------

The engine's prefill and decode entry points each compile exactly once
per engine and stay cached across every weight swap of the run:

* cache pytree structure and all avals are pinned at construction
  (static-shape paged pool + page table; position is a traced scalar);
* ``swap_params`` validates a candidate's treedef and leaf avals against
  the pinned signature BEFORE installing it — a structural change raises
  instead of adding a jit cache entry;
* sampling (temperature, PRNG key) is traced data inside the step.

Enforced by ``analysis.lint.audit_compile_once`` over
``ServeEngine.compile_once_probe`` (the decode step under cycling weight
variants — the serve cell of ``analysis.lint.sweep_registry``) and
benchmarked by ``benchmarks/run.py fed_serve_swap`` (swap-heavy decode
>= 0.9x the static-server token rate).
"""
from repro.serve.engine import ServeEngine
from repro.serve.gate import PromotionGate, PromotionLog, PromotionRecord, heldout_batches
from repro.serve.session import ServeSession, ServeSummary
from repro.serve.swap import Candidate, CheckpointWatcher

__all__ = [
    "ServeEngine",
    "Candidate",
    "CheckpointWatcher",
    "PromotionGate",
    "PromotionLog",
    "PromotionRecord",
    "heldout_batches",
    "ServeSession",
    "ServeSummary",
]
