"""The batched decode engine: prefill/decode split over the paged KV-cache.

``ServeEngine`` owns the two jitted entry points of the serving hot path —
``prefill + first-token sample`` and ``single-token decode + sample`` — over
a preallocated static-shape paged KV-cache (``models.attention``: a
``(B*P, page_size, KV, hd)`` pool indexed through a ``(B, P)`` page table).
Both entry points compile exactly once per engine and stay cached across
weight swaps:

* the cache pytree structure and every aval (shape/dtype) are pinned at
  construction — ``prefill`` allocates them, ``decode`` threads them
  unchanged, and ``swap_params`` validates a candidate against the pinned
  param treedef/avals before accepting it, so no call can ever present a
  new signature to the jit cache;
* sampling runs *inside* the jitted step with the temperature as a traced
  f32 scalar and a fresh per-call PRNG key, so greedy vs. stochastic
  decoding is a data change, not a recompile — and the first generated
  token (sampled from the prefill logits) respects the temperature exactly
  like every later one;
* ``swap_params`` happens between decode steps on the host: in-flight
  sequences keep their caches, positions, and last tokens, only the param
  arrays under the (structurally identical) pytree change.

``analysis.lint.audit_compile_once`` enforces the contract through
``compile_once_probe()``, which adapts the decode entry point to the
segment-runner probe interface (``_lint`` / ``_cache_size`` handles) and
cycles candidate params per call — i.e. the audited program IS the decode
step under continuous weight swaps.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer

__all__ = ["ServeEngine"]


def _leaf_avals(tree) -> list:
    """[(path, shape, dtype_name)] in flatten order — the pinned signature."""
    return [
        (jax.tree_util.keystr(path), tuple(x.shape), jnp.asarray(x).dtype.name)
        for path, x in jax.tree_util.tree_leaves_with_path(tree)
    ]


def _sample_token(logits: jax.Array, key: jax.Array, temperature: jax.Array):
    """(B, 1, V) logits -> (B, 1) int32 next tokens.

    Temperature is a *traced* scalar: ``temperature > 0`` selects stochastic
    sampling (logits scaled by ``1/temperature``), else argmax — one compiled
    program serves both, and the prefill's first token goes through the same
    path as every decode token (the old launcher's always-greedy-first bug)."""
    lg = logits[:, -1].astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1)
    sampled = jax.random.categorical(key, lg / jnp.maximum(temperature, 1e-6), axis=-1)
    return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)[:, None]


class ServeEngine:
    """Lockstep batched generation with hot-swappable weights.

    Parameters
    ----------
    cfg:
        ``repro.models.common.ArchConfig`` (LM archs; frontend/aux archs are
        rejected — serving traffic is token prompts).
    params:
        Initial weights; their treedef + avals become the pinned swap
        contract.
    batch / max_seq / page_size:
        Static decode geometry: ``batch`` lockstep sequences, each with a
        ``max_seq``-token paged cache of ``page_size``-token pages.
    temperature:
        Default sampling temperature (per-call override via ``start``/
        ``step`` is deliberately absent: it is traced data, set per engine).
    seed:
        Seeds the engine's *sampling* key stream only — prompt synthesis and
        param init are the caller's keys (split per use, never shared).
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        batch: int,
        max_seq: int,
        page_size: int = 16,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        if getattr(cfg, "frontend", None):
            raise ValueError(
                f"ServeEngine serves token-prompt LM archs; {cfg.name!r} has a "
                f"frontend ({cfg.frontend!r}) needing aux embeddings"
            )
        if max_seq < 2:
            raise ValueError(f"max_seq must be >= 2, got {max_seq}")
        self.cfg = cfg
        self.batch = int(batch)
        self.max_seq = int(max_seq)
        self.page_size = int(page_size)
        self.temperature = float(temperature)
        self._key = jax.random.PRNGKey(seed)

        self._params = jax.device_put(params)
        self._param_treedef = jax.tree_util.tree_structure(params)
        self._param_avals = _leaf_avals(params)
        self.swaps = 0

        # In-flight generation state (None until start()).
        self._tok = None
        self._caches = None
        self._index = 0
        self._out: list = []

        # Decode-side accounting (prefill excluded: tokens/sec is the decode
        # steady state the bench gates).
        self.decode_tokens = 0
        self.decode_seconds = 0.0

        def _prefill(p, prompts, key, temperature):
            logits, caches = transformer.prefill(
                p, cfg, prompts, max_seq=max_seq, page_size=page_size
            )
            return _sample_token(logits, key, temperature), logits, caches

        def _decode(p, tok, caches, index, key, temperature):
            logits, caches = transformer.decode_step(p, cfg, tok, caches, index)
            return _sample_token(logits, key, temperature), logits, caches

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # -- generation ----------------------------------------------------------
    @property
    def params(self):
        return self._params

    @property
    def index(self) -> int:
        """Tokens currently in the cache (= next write position)."""
        return self._index

    @property
    def capacity(self) -> int:
        """Decode steps possible before the paged cache is full."""
        return self.max_seq - self._index

    def _temp(self):
        return jnp.asarray(self.temperature, jnp.float32)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def start(self, prompts) -> jax.Array:
        """Prefill a fresh prompt batch; returns the first sampled tokens.

        Replaces any previous in-flight batch (the lockstep refill: serve
        traffic as back-to-back full batches)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        if prompts.shape[0] != self.batch or prompts.ndim != 2:
            raise ValueError(
                f"prompts must be ({self.batch}, prompt_len), got {prompts.shape}"
            )
        if prompts.shape[1] >= self.max_seq:
            raise ValueError(
                f"prompt_len {prompts.shape[1]} must leave decode room under "
                f"max_seq={self.max_seq}"
            )
        tok, _, caches = self._prefill(
            self._params, prompts, self._next_key(), self._temp()
        )
        self._tok, self._caches = tok, caches
        self._index = int(prompts.shape[1])
        self._out = [tok]
        return tok

    def step(self, n: int = 1) -> int:
        """Run up to ``n`` decode steps (bounded by cache capacity).

        Returns the number of steps executed; accumulates decode-side
        wall-clock for ``tokens_per_sec``."""
        if self._tok is None:
            raise RuntimeError("no in-flight batch; call start(prompts) first")
        n = min(int(n), self.capacity)
        if n <= 0:
            return 0
        t0 = time.perf_counter()
        tok, caches = self._tok, self._caches
        for _ in range(n):
            tok, _, caches = self._decode(
                self._params,
                tok,
                caches,
                jnp.asarray(self._index, jnp.int32),
                self._next_key(),
                self._temp(),
            )
            self._index += 1
            self._out.append(tok)
        jax.block_until_ready(tok)
        self._tok, self._caches = tok, caches
        self.decode_seconds += time.perf_counter() - t0
        self.decode_tokens += n * self.batch
        return n

    def generated(self) -> jax.Array:
        """All tokens sampled for the current batch, (B, n_generated)."""
        if not self._out:
            return jnp.zeros((self.batch, 0), jnp.int32)
        return jnp.concatenate(self._out, axis=1)

    def tokens_per_sec(self) -> float:
        return self.decode_tokens / max(self.decode_seconds, 1e-9)

    # -- the hot swap --------------------------------------------------------
    def swap_params(self, new_params) -> None:
        """Install candidate weights between decode steps.

        Validates the candidate against the pinned treedef and avals FIRST:
        a structurally different pytree (or any shape/dtype drift) raises
        instead of poisoning the jit cache with a second entry.  In-flight
        sequences are untouched — caches, positions, and last tokens carry
        straight into the next decode step under the new weights."""
        treedef = jax.tree_util.tree_structure(new_params)
        if treedef != self._param_treedef:
            raise ValueError(
                f"swap_params: param treedef changed\n  pinned: "
                f"{self._param_treedef}\n  candidate: {treedef}"
            )
        for (path, shape, dtype), (_, got_shape, got_dtype) in zip(
            self._param_avals, _leaf_avals(new_params)
        ):
            if (shape, dtype) != (got_shape, got_dtype):
                raise ValueError(
                    f"swap_params: param aval drift at {path}: pinned "
                    f"{shape}/{dtype}, candidate {got_shape}/{got_dtype} — "
                    "a swap must match the pinned signature exactly"
                )
        self._params = jax.device_put(new_params)
        self.swaps += 1

    # -- lint handles --------------------------------------------------------
    def decode_cache_entries(self) -> int:
        """Jit cache entries of the decode entry point (compile-once: 1)."""
        return int(self._decode._cache_size())

    def prefill_cache_entries(self) -> int:
        return int(self._prefill._cache_size())

    def decode_jaxpr(self, prompt_len: int | None = None):
        """The decode step's jaxpr on this engine's pinned avals — the input
        ``analysis.lint.audit_dtypes`` audits in the serve lint cell."""
        plen = int(prompt_len) if prompt_len is not None else self.max_seq // 2
        caches = transformer.init_caches(
            self.cfg, self.batch, self.max_seq, page_size=self.page_size
        )
        tok = jnp.zeros((self.batch, 1), jnp.int32)
        return jax.make_jaxpr(
            lambda p, t, c, i, k, temp: self._decode(p, t, c, i, k, temp)
        )(
            self._params,
            tok,
            caches,
            jnp.asarray(plen, jnp.int32),
            jax.random.PRNGKey(0),
            jnp.asarray(self.temperature, jnp.float32),
        )

    def compile_once_probe(self, prompts, param_variants=None):
        """(probe_fn, init_state) for ``analysis.lint.audit_compile_once``.

        The probe adapts the decode entry point to the segment-runner probe
        interface: ``probe(state, n_rounds) -> state`` with ``state = (tok,
        caches, index, key)`` — every leaf an array, so the audit's numpy
        round trip (the checkpoint transport) applies cleanly.  Each *call*
        installs the next entry of ``param_variants`` (cycling), so the
        audit's ``n_segments + 1`` calls execute the decode step across >= 2
        weight swaps; the jit cache must still grow by exactly one.

        ``_lint`` declares ``donate=False`` (the engine never donates: the
        carried caches must survive a failed swap), ``_cache_size`` forwards
        the decode PjitFunction's counter."""
        variants = [jax.device_put(v) for v in (param_variants or [self._params])]
        for v in variants[1:]:
            if jax.tree_util.tree_structure(v) != self._param_treedef:
                raise ValueError("compile_once_probe: variant treedef mismatch")
        calls = {"n": 0}
        temp = jnp.asarray(self.temperature, jnp.float32)
        decode = self._decode

        tok, _, caches = self._prefill(
            variants[0], jnp.asarray(prompts, jnp.int32),
            jax.random.PRNGKey(1), temp,
        )
        init_state = (
            tok,
            caches,
            jnp.asarray(int(prompts.shape[1]), jnp.int32),
            jax.random.PRNGKey(2),
        )

        def probe(state, n_rounds: int):
            tok, caches, index, key = state
            p = variants[calls["n"] % len(variants)]
            calls["n"] += 1
            for _ in range(int(n_rounds)):
                key, sub = jax.random.split(key)
                tok, _, caches = decode(p, tok, caches, index, sub, temp)
                index = index + jnp.int32(1)
            return (tok, caches, index, key)

        probe._lint = {"donate": False, "donate_argnums": ()}
        probe._cache_size = decode._cache_size
        return probe, init_state
