"""Checkpoint watcher: the read side of the manifest hand-off contract.

``CheckpointWatcher`` follows a ``repro.checkpoint.CheckpointManager``
directory written by a (possibly still running) training process and turns
newly *committed* steps into restore-validated ``Candidate``s for the
promotion gate.  It never parses checkpoint files on its own — everything
goes through the manager's read path, so the full contract applies:

* the manifest (``manifest.json``, written via tmp + ``os.replace``) is the
  atomic commit point: a step is visible if and only if its checkpoint
  files were completely written first — a watcher can never observe a torn
  step (``CheckpointManager`` module docstring);
* ``restore`` validates the manifest's config fingerprint against the
  watcher's manager (train and serve must agree on the spec) and the
  treedef hash against the restore template — a candidate that deserializes
  is structurally identical to what the engine's pinned swap signature
  expects.

The watcher is strictly monotone: each committed step is surfaced at most
once (``seen_step`` advances on every successful ``poll``), so the serving
loop considers every boundary exactly once even when it polls faster than
training publishes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["Candidate", "CheckpointWatcher"]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One committed checkpoint boundary, restored and ready to score.

    ``params`` is what the promotion gate scores and the engine swaps in;
    ``state`` is the full restored carry (``fed.state.TrainState`` for the
    zoo stack) for provenance/debugging."""

    step: int
    params: Any
    state: Any = None


class CheckpointWatcher:
    """Follow a manager directory; yield each new committed step once.

    Parameters
    ----------
    manager:
        A ``CheckpointManager`` opened on the training run's directory with
        the run's config fingerprint (restore refuses a foreign run).
    template:
        The restore template — ``repro.api.restore_template(spec)``'s fresh
        round-0 ``TrainState`` for zoo runs.
    extract:
        Restored state -> swap payload; default takes ``.params`` (falling
        back to the state itself for plain-dict checkpoints).
    """

    def __init__(self, manager, template, *, extract: Callable | None = None):
        self.manager = manager
        self.template = template
        self.extract = extract or (lambda s: getattr(s, "params", s))
        self.seen_step = 0  # committed steps are rounds-done, always >= 1

    def poll(self) -> Candidate | None:
        """The newest committed step beyond ``seen_step``, or None.

        Intermediate steps the trainer published while we weren't looking
        are skipped, not queued: serving always converges on the newest
        committed boundary (the gate scores what would actually be served)."""
        step = self.manager.latest()
        if step is None or int(step) <= self.seen_step:
            return None
        state = self.manager.restore(self.template, int(step))
        self.seen_step = int(step)
        return Candidate(step=int(step), params=self.extract(state), state=state)

    def wait(self, timeout: float) -> Candidate | None:
        """Block (bounded) for a step beyond ``seen_step``; restore it.

        Built on ``CheckpointManager.wait_for_next`` — the atomic-manifest
        read semantics mean the returned candidate's files are guaranteed
        complete."""
        step = self.manager.wait_for_next(self.seen_step, timeout)
        if step is None:
            return None
        return self.poll()
