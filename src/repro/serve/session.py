"""The serving loop: decode continuously, swap at committed boundaries.

``ServeSession`` composes the three layers — ``ServeEngine`` (paged-cache
decode), ``CheckpointWatcher`` (manifest follow), ``PromotionGate``
(held-out-loss promote/rollback) — into the closed train-to-serve loop:

    while traffic:
        decode a chunk of tokens (lockstep batch, paged cache)
        poll the manifest for a newly committed boundary
        if one appeared: score it; promote -> hot-swap, rollback -> keep

Decoding never stops for training: the watcher's poll is a bounded wait
between decode chunks, a promoted candidate swaps in between two decode
steps (in-flight sequences keep their caches), and a rollback costs one
eval — the engine's jit cache stays at one decode entry throughout, which
is why swap-heavy serving sustains ~the static-server token rate
(``benchmarks/run.py fed_serve_swap``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["ServeSummary", "ServeSession"]


@dataclasses.dataclass
class ServeSummary:
    """What one ``ServeSession.run`` did, for logs and CI assertions."""

    tokens: int
    tokens_per_sec: float
    promotions: int
    rollbacks: int
    swaps: int
    last_step: int
    batches_served: int

    def render(self) -> str:
        # Machine-readable: the CI serve-smoke job greps this exact shape.
        return (
            f"serve summary: promotions={self.promotions} "
            f"rollbacks={self.rollbacks} tokens={self.tokens} "
            f"tokens_per_sec={self.tokens_per_sec:.1f} swaps={self.swaps} "
            f"last_step={self.last_step} batches={self.batches_served}"
        )


class ServeSession:
    """Drive an engine under traffic while following a training run.

    Parameters
    ----------
    engine / watcher / gate:
        The three serve layers, already constructed (the gate primed or
        not — ``run`` primes it with the engine's current params when
        ``gate.best_loss`` is unset).
    prompt_fn:
        () -> (batch, prompt_len) int32 prompts — the traffic source.
        Called for the initial batch and at every lockstep refill (cache
        full -> fresh prefill).
    decode_steps_per_poll:
        Decode chunk length between manifest polls — the swap latency /
        throughput knob.
    final_step:
        Stop once a boundary >= this step has been considered (the
        training horizon: ``spec.federation.rounds``).  None = run until
        ``timeout``.
    on_decision:
        Optional callback ``(candidate, promoted)`` after each gate
        decision (progress printing).
    """

    def __init__(
        self,
        engine,
        watcher,
        gate,
        *,
        prompt_fn: Callable,
        decode_steps_per_poll: int = 16,
        final_step: int | None = None,
        on_decision: Callable | None = None,
    ):
        self.engine = engine
        self.watcher = watcher
        self.gate = gate
        self.prompt_fn = prompt_fn
        self.decode_steps_per_poll = int(decode_steps_per_poll)
        self.final_step = final_step
        self.on_decision = on_decision

    def run(self, *, timeout: float = 120.0, poll_timeout: float = 0.2) -> ServeSummary:
        """Serve until the training horizon is consumed (or ``timeout``).

        ``poll_timeout`` bounds how long the loop blocks on the manifest
        between decode chunks when the cache still has capacity; the decode
        side never waits longer than that for the trainer."""
        engine, watcher, gate = self.engine, self.watcher, self.gate
        if gate.best_loss is None:
            gate.prime(engine.params)
        engine.start(self.prompt_fn())
        batches = 1
        deadline = time.monotonic() + float(timeout)
        while True:
            if engine.capacity <= 0:
                engine.start(self.prompt_fn())
                batches += 1
            engine.step(min(self.decode_steps_per_poll, engine.capacity))
            candidate = watcher.wait(poll_timeout)
            if candidate is not None:
                promoted = gate.consider(candidate)
                if promoted:
                    engine.swap_params(candidate.params)
                if self.on_decision is not None:
                    self.on_decision(candidate, promoted)
            done = (
                self.final_step is not None
                and watcher.seen_step >= self.final_step
            )
            if done or time.monotonic() >= deadline:
                break
        return ServeSummary(
            tokens=engine.decode_tokens,
            tokens_per_sec=engine.tokens_per_sec(),
            promotions=gate.log.promotions,
            rollbacks=gate.log.rollbacks,
            swaps=engine.swaps,
            last_step=watcher.seen_step,
            batches_served=batches,
        )
