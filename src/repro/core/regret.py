"""Regret and sampling-quality metrics (Sections 4-5).

Used by the experiment drivers to reproduce the paper's Figure 2/3/6 curves:

* dynamic regret   Regret_D(T) = sum_t l_t(p^t) - sum_t min_p l_t(p)   (eq. 8)
* static  regret   Regret_S(T) = sum_t l_t(p^t) - min_p sum_t l_t(p)   (eq. 9)
* sampling quality Q(S^t) upper bound l_t(p^t) - l_t(p*)               (Sec 5.1)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver

__all__ = ["RegretTracker", "round_costs"]


def round_costs(
    full_scores: jax.Array, p_used: jax.Array, budget: float | jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Device-side per-round online costs: (l_t(p^t), min_p l_t(p)).

    Jittable/scan-safe counterpart of ``RegretTracker.record`` — the compiled
    server loop emits these as stacked per-round buffers and materializes a
    ``RegretTracker`` view once at the end via ``RegretTracker.from_arrays``.
    """
    cost = solver.expected_cost(full_scores, p_used)
    opt = solver.optimal_cost(full_scores, budget)
    return cost, opt


@dataclasses.dataclass
class RegretTracker:
    """Accumulates per-round online costs from *full* feedback (simulation-side

    oracle knowledge — available in experiments, not on a real server)."""

    budget: int
    costs: list = dataclasses.field(default_factory=list)  # l_t(p^t)
    opt_costs: list = dataclasses.field(default_factory=list)  # min_p l_t(p)
    score_history: list = dataclasses.field(default_factory=list)

    def record(self, full_scores: jax.Array, p_used: jax.Array) -> None:
        full_scores = np.asarray(full_scores)
        p_used = np.asarray(p_used)
        cost = float(solver.expected_cost(full_scores, p_used))
        opt = float(solver.optimal_cost(full_scores, self.budget))
        self.costs.append(cost)
        self.opt_costs.append(opt)
        self.score_history.append(full_scores)

    @classmethod
    def from_arrays(
        cls,
        budget: int,
        costs,
        opt_costs,
        score_history=None,
    ) -> "RegretTracker":
        """Post-hoc view over stacked on-device buffers (T,), (T,), (T, N)
        produced inside the compiled scan loop.  ``score_history=None``
        (FedConfig.track_scores=False) yields an empty history — the regret
        curves still work, only score-replay diagnostics are unavailable."""
        costs = np.asarray(costs)
        opt_costs = np.asarray(opt_costs)
        score_history = np.zeros((0, 0)) if score_history is None else np.asarray(score_history)
        return cls(
            budget=budget,
            costs=[float(c) for c in costs],
            opt_costs=[float(c) for c in opt_costs],
            score_history=[score_history[t] for t in range(score_history.shape[0])],
        )

    # -- metrics ---------------------------------------------------------

    def dynamic_regret(self) -> np.ndarray:
        """Cumulative eq. (8) per round."""
        c = np.asarray(self.costs)
        o = np.asarray(self.opt_costs)
        return np.cumsum(c - o)

    def static_regret(self) -> float:
        """eq. (9) first term: vs the best fixed p in hindsight.

        Needs the per-round score history; unavailable when the run opted out
        via ``FedConfig.track_scores=False``."""
        if not self.score_history:
            raise ValueError(
                "static_regret needs score_history; this run recorded none "
                "(FedConfig.track_scores=False or no rounds)"
            )
        hist = np.stack(self.score_history)  # (T, N)
        cum_sq = np.sqrt(np.sum(hist**2, axis=0))  # sqrt(pi^2_{1:T}(i))
        p_star = np.asarray(solver.isp_probabilities(jnp.asarray(cum_sq), self.budget))
        best_fixed = sum(
            float(solver.expected_cost(jnp.asarray(s), jnp.asarray(p_star)))
            for s in self.score_history
        )
        return float(np.sum(self.costs) - best_fixed)

    def quality_gap(self) -> np.ndarray:
        """Per-round Q(S^t) upper bound l_t(p^t) - l_t(p^*_t)."""
        return np.asarray(self.costs) - np.asarray(self.opt_costs)
