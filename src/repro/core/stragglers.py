"""Client availability / stragglers (paper Appendix E.1).

In cross-device FL a subset A^t ~ q of clients is available each round
(devices busy, offline, or slow).  The estimator stays unbiased by sampling
only from A^t and importance-correcting with the availability probability:

    d^t = sum_{i in S^t subseteq A^t} lambda_i g_i / (q_i p_i)

``available_draw`` composes any base sampler's draw with an availability
mask; ``availability_weights`` produces the corrected estimator weights.
The sampler's own feedback update keeps using p~ (its sampling randomness);
availability is exogenous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.samplers import SampleResult

__all__ = ["available_draw", "availability_weights"]


def available_draw(draw: SampleResult, avail_mask: jax.Array) -> SampleResult:
    """Restrict a draw to the available set A^t (exogenous Bernoulli(q))."""
    mask = jnp.logical_and(draw.mask, avail_mask)
    counts = jnp.where(avail_mask, draw.counts, 0)
    return SampleResult(
        mask=mask, counts=counts, marginals=draw.marginals, draw_probs=draw.draw_probs
    )


def availability_weights(
    draw: SampleResult, lam: jax.Array, q: jax.Array, procedure: str, budget: int
) -> jax.Array:
    """Estimator weights with the 1/q availability correction."""
    from repro.core.estimator import client_weights

    w = client_weights(draw, lam, procedure, budget)
    return w / jnp.maximum(jnp.asarray(q), 1e-30)
