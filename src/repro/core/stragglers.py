"""Deployment realism: availability, deadline stragglers, buffered-async.

In cross-device FL a subset A^t ~ q of clients is available each round
(devices busy, offline, or slow).  The estimator stays unbiased by sampling
only from A^t and importance-correcting with the availability probability
(paper Appendix E.1; worked out in general in "A General Theory for Client
Sampling in Federated Learning", arXiv 2107.12211):

    d^t = sum_{i in S^t subseteq A^t} lambda_i g_i / (q_i p_i)

This module is the scan-safe fault layer BOTH compiled stacks run inside
their traced round bodies, switched by the ``repro.api.FaultSpec`` section
of an ``ExperimentSpec``.  Compiled entry points that consume it:

* ``repro.fed.server._build_round_body`` — the simulation stack's round body
  (both the segmented ``lax.scan`` path and the per-round reference loop);
* ``repro.fed.round._build_scan_body`` — the pod-scale compiled round body
  (``build_fed_scan_segment`` / ``repro.launch.train --compiled``);
* ``repro.analysis.lint.sweep_registry`` — the faulted lint cell traces the
  availability-composed bodies through the same auditors as the clean ones.

Three components, all pure functions of (fault config, carried state, round
index, PRNG key) so they ride ``lax.scan`` and checkpoint/resume bit-for-bit:

1. **Availability processes** (``availability_step``): static Bernoulli(q),
   a per-client Markov on/off chain (the carried (N,) ``chain`` state), and
   a deterministic diurnal schedule.  The returned per-round availability
   probability ``q^t`` is the *conditional* inclusion probability given the
   carried chain state, so the ``1/q`` correction is conditionally — hence
   unconditionally — unbiased.  ``available_draw`` composes the mask AND the
   probabilities into the draw, making downstream ``client_weights`` the
   availability-corrected estimator with no further bookkeeping.
2. **Deadline stragglers** (``latency_draw`` + ``deadline_survival``):
   per-client latency drawn in-trace from a spec-configured distribution;
   clients past the round deadline are masked out AFTER local training is
   scheduled, and survivor weights are rescaled by the inverse survival
   probability ``1 / P(latency <= deadline)`` (a static build-time float) so
   the estimate stays unbiased.
3. **Buffered-async aggregation** (``async_step`` / ``flush_pending``): the
   server carries a (B, D) stale-delta ring buffer; each round's aggregate is
   "dispatched" with an in-trace latency-derived arrival round, applied with
   a ``staleness_discount ** staleness`` factor when it arrives, and any
   still-pending deltas are flushed once after the horizon completes.  The
   buffer lives in the canonical ``TrainState`` carry, so mid-run segment
   boundaries stay bitwise-neutral and SIGKILL/resume is exact.

The sampler's own feedback update keeps using p~ (its sampling randomness);
availability is exogenous.
"""
from __future__ import annotations

import math
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.samplers import SampleResult

__all__ = [
    "ZeroAvailabilityError",
    "available_draw",
    "availability_weights",
    "availability_init",
    "availability_step",
    "latency_draw",
    "deadline_survival",
    "fault_state_init",
    "abstract_fault_state",
    "async_step",
    "flush_pending",
    "flat_dim",
    "tree_to_vec",
    "vec_to_tree",
]


class ZeroAvailabilityError(ValueError):
    """A drawn client has availability probability q == 0: its contribution
    can never be observed and no finite importance weight corrects for it.
    (The pre-fix code silently clamped q at 1e-30, yielding a ~1e30 weight.)
    """


def available_draw(
    draw: SampleResult, avail_mask: jax.Array, q: jax.Array | None = None
) -> SampleResult:
    """Restrict a draw to the available set A^t and (with ``q``) compose the
    availability probability into the draw's own probabilities.

    Contract: with ``q`` given, the returned draw's ``marginals`` and
    ``draw_probs`` are the *effective* inclusion probabilities ``q * p`` —
    the probability a client is both sampled AND available — so a plain
    ``estimator.client_weights`` call on the composed draw yields the
    availability-corrected weights ``lam / (q p)`` (ISP) or
    ``counts lam / (K q q_draw)`` (RSP) with no further bookkeeping.
    Clients with ``q == 0`` are excluded by the composed mask, so their
    weight is zero (the in-trace mask-to-zero guarantee) rather than the
    ~1e30 blowup a downstream ``1/max(p, 1e-30)`` clamp would produce.

    Without ``q`` (legacy two-step form) the probabilities are returned
    UNCORRECTED — the caller must apply ``availability_weights`` for the
    ``1/q`` factor; feeding the uncomposed draw to plain ``client_weights``
    yields a biased estimate.
    """
    mask = jnp.logical_and(draw.mask, avail_mask)
    counts = jnp.where(avail_mask, draw.counts, 0)
    if q is None:
        return SampleResult(
            mask=mask,
            counts=counts,
            marginals=draw.marginals,
            draw_probs=draw.draw_probs,
        )
    qf = jnp.asarray(q, jnp.float32)
    # Exclude q == 0 clients from the mask even if the exogenous mask said
    # available (a deterministic schedule's q is exactly its 0/1 mask, but a
    # caller-supplied q may disagree with its sampled mask realization).
    mask = jnp.logical_and(mask, qf > 0.0)
    return SampleResult(
        mask=mask,
        counts=counts,
        marginals=qf * draw.marginals,
        draw_probs=qf * draw.draw_probs,
    )


def availability_weights(
    draw: SampleResult, lam: jax.Array, q: jax.Array, procedure: str, budget: int
) -> jax.Array:
    """Estimator weights with the 1/q availability correction (legacy
    two-step form: ``draw`` is availability-MASKED but its probabilities are
    the sampler's own, i.e. ``available_draw(draw, avail)`` without ``q``).

    Prefer composing via ``available_draw(draw, avail, q)`` + plain
    ``client_weights`` — it is the same correction by construction.  A drawn
    client with ``q_i == 0`` is a modeling error (its update is never
    observable): on the host path this raises :class:`ZeroAvailabilityError`;
    in-trace (where raising is impossible) the weight is masked to zero.
    """
    from repro.core.estimator import client_weights

    q_arr = jnp.asarray(q, jnp.float32)
    w = client_weights(draw, lam, procedure, budget)
    concrete = not any(
        isinstance(x, jax.core.Tracer) for x in (draw.mask, q_arr, w)
    )
    if concrete:
        bad = np.asarray(jnp.logical_and(draw.mask, q_arr <= 0.0))
        if bad.any():
            raise ZeroAvailabilityError(
                f"clients {np.nonzero(bad)[0].tolist()} were drawn with "
                "availability probability q == 0; no finite importance "
                "weight corrects for a never-observable client"
            )
    return jnp.where(q_arr > 0.0, w / jnp.where(q_arr > 0.0, q_arr, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Availability processes (FaultSpec.availability)
# ---------------------------------------------------------------------------


def availability_init(fault: Any, n: int) -> jax.Array | None:
    """Carried chain state for the availability process, or ``None``.

    Only the Markov on/off process is stateful: its (N,) bool chain starts
    all-on (a deterministic warm start — round 0's conditional availability
    is then exactly ``1 - p_off``, which the correction uses, so the
    estimator is unbiased from the first round)."""
    if getattr(fault, "availability", None) == "markov":
        return jnp.ones((n,), bool)
    return None


def availability_step(
    fault: Any, chain: jax.Array | None, t: jax.Array, key: jax.Array, n: int
):
    """One round of the availability process.

    Returns ``(mask, q, new_chain)``: the (N,) bool availability mask A^t,
    the (N,) f32 per-client availability probability ``q^t`` the 1/q
    correction must use — for the Markov chain this is the probability
    CONDITIONAL on the carried previous state, which is what makes the
    corrected estimator unbiased round by round — and the advanced chain
    state (``chain`` unchanged for the stateless processes).
    """
    mode = fault.availability
    kw = dict(fault.availability_kwargs)
    if mode == "bernoulli":
        q = jnp.broadcast_to(
            jnp.asarray(kw.get("q", 0.9), jnp.float32), (n,)
        ).astype(jnp.float32)
        mask = jax.random.uniform(key, (n,)) < q
        return mask, q, chain
    if mode == "markov":
        p_on = float(kw.get("p_on", 0.5))  # P(off -> on)
        p_off = float(kw.get("p_off", 0.5))  # P(on -> off)
        q = jnp.where(chain, 1.0 - p_off, p_on).astype(jnp.float32)
        mask = jax.random.uniform(key, (n,)) < q
        return mask, q, mask
    if mode == "diurnal":
        # Deterministic schedule: client i is on duty when the fractional
        # phase of (t / period + i / N) falls inside the duty cycle.  q is
        # exactly the 0/1 mask — offline clients are excluded (weight zero),
        # not importance-corrected (no finite weight exists for q == 0).
        period = float(kw.get("period", 24.0))
        duty = float(kw.get("duty", 0.5))
        phase = jnp.arange(n, dtype=jnp.float32) / jnp.float32(n)
        frac = jnp.mod(
            jnp.asarray(t, jnp.float32) / jnp.float32(period) + phase, 1.0
        )
        mask = frac < jnp.float32(duty)
        return mask, mask.astype(jnp.float32), chain
    raise ValueError(f"unknown availability process {mode!r}")


# ---------------------------------------------------------------------------
# Latency / deadline stragglers (FaultSpec.deadline, .latency)
# ---------------------------------------------------------------------------


def latency_draw(fault: Any, shape: tuple, key: jax.Array) -> jax.Array:
    """Per-client latency sample from the spec-configured distribution."""
    dist = fault.latency
    kw = dict(fault.latency_kwargs)
    if dist == "exponential":
        scale = float(kw.get("scale", 1.0))
        return scale * jax.random.exponential(key, shape, jnp.float32)
    if dist == "uniform":
        lo = float(kw.get("lo", 0.0))
        hi = float(kw.get("hi", 1.0))
        return jax.random.uniform(key, shape, jnp.float32, lo, hi)
    if dist == "lognormal":
        mu = float(kw.get("mu", 0.0))
        sigma = float(kw.get("sigma", 1.0))
        return jnp.exp(mu + sigma * jax.random.normal(key, shape, jnp.float32))
    raise ValueError(f"unknown latency distribution {dist!r}")


def deadline_survival(fault: Any) -> float:
    """P(latency <= deadline) as a static build-time float — the survivor
    weights are rescaled by its inverse so deadline dropout stays unbiased:
    E[1{survive} w g / r] = w g.  Raises when the survival probability is
    (numerically) zero: every client would always miss the deadline and no
    reweighting can recover the estimate."""
    d = float(fault.deadline)
    dist = fault.latency
    kw = dict(fault.latency_kwargs)
    if dist == "exponential":
        scale = float(kw.get("scale", 1.0))
        r = 1.0 - math.exp(-d / scale)
    elif dist == "uniform":
        lo = float(kw.get("lo", 0.0))
        hi = float(kw.get("hi", 1.0))
        r = 1.0 if hi <= lo else min(max((d - lo) / (hi - lo), 0.0), 1.0)
        if hi <= lo and d < lo:
            r = 0.0
    elif dist == "lognormal":
        mu = float(kw.get("mu", 0.0))
        sigma = float(kw.get("sigma", 1.0))
        if d <= 0.0:
            r = 0.0
        else:
            r = 0.5 * (1.0 + math.erf((math.log(d) - mu) / (sigma * math.sqrt(2.0))))
    else:
        raise ValueError(f"unknown latency distribution {dist!r}")
    if r <= 1e-12:
        raise ValueError(
            f"deadline={d} gives survival probability ~{r:.3g} under "
            f"latency={dist!r} {dict(kw)}: every client always misses the "
            "deadline and no reweighting can keep the estimator unbiased"
        )
    return r


# ---------------------------------------------------------------------------
# Fault state: the TrainState-carried pytree
# ---------------------------------------------------------------------------


def fault_state_init(fault: Any, n: int, d_dim: int = 0, compression: Any = None) -> dict:
    """The fault layer's carried state: a (possibly empty) dict pytree that
    lives in ``TrainState.faults`` so every piece of fault dynamics —
    availability chain, stale-delta buffer — rides segment boundaries and
    checkpoints bit-for-bit.  Which keys exist is a static function of the
    fault config (stable treedef per spec):

    * ``chain`` — (N,) bool Markov availability state (markov mode only);
    * ``buf``   — the (B, D) stale-delta ring: ``delta`` (B, D) f32,
      ``dispatch``/``arrival`` (B,) int32, ``valid`` (B,) bool
      (``async_buffer > 0`` only; D is the flattened update dimension).
      With an enabled ``compression`` the ring itself holds quantized width:
      ``delta`` becomes (B, D_pad) int8|fp8 plus a ``scale`` (B, nb) f32
      entry (the dominant carried/checkpointed buffer drops ~4x).  Ring
      requantization error is NOT error-feedback-corrected — pending deltas
      are already-dispatched network payloads.
    """
    state: dict = {}
    chain = availability_init(fault, n)
    if chain is not None:
        state["chain"] = chain
    b = int(getattr(fault, "async_buffer", 0) or 0)
    if b > 0:
        if compression is not None:
            from repro.kernels.fused_weighted_agg import quant_dtype

            sb = int(compression.scale_block)
            nb = -(-int(d_dim) // sb)
            state["buf"] = {
                "delta": jnp.zeros((b, nb * sb), quant_dtype(compression.delta_dtype)),
                "scale": jnp.ones((b, nb), jnp.float32),
                "dispatch": jnp.zeros((b,), jnp.int32),
                "arrival": jnp.zeros((b,), jnp.int32),
                "valid": jnp.zeros((b,), bool),
            }
        else:
            state["buf"] = {
                "delta": jnp.zeros((b, int(d_dim)), jnp.float32),
                "dispatch": jnp.zeros((b,), jnp.int32),
                "arrival": jnp.zeros((b,), jnp.int32),
                "valid": jnp.zeros((b,), bool),
            }
    return state


def abstract_fault_state(fault: Any, n: int, d_dim: int = 0, compression: Any = None):
    """ShapeDtypeStruct pytree of ``fault_state_init`` (no allocation)."""
    return jax.eval_shape(lambda: fault_state_init(fault, n, d_dim, compression))


# ---------------------------------------------------------------------------
# Buffered-asynchronous aggregation (FaultSpec.async_buffer)
# ---------------------------------------------------------------------------


def _round_time(fault: Any) -> float:
    rt = getattr(fault, "round_time", None)
    if rt is None:
        rt = getattr(fault, "deadline", None)
    return float(rt) if rt is not None else 1.0


def _ring_dequant_apply(buf: dict, coef: jax.Array, delta=None, scale=None) -> jax.Array:
    """(B,) coefficients against a quantized ring: blockwise dequantize and
    contract in one einsum — (B,) x (B, nb, sb) -> (D_pad,)."""
    delta = buf["delta"] if delta is None else delta
    scale = buf["scale"] if scale is None else scale
    b, d_pad = delta.shape
    nb = scale.shape[1]
    blocks = delta.astype(jnp.float32).reshape(b, nb, d_pad // nb)
    return jnp.einsum("b,bns->ns", coef, blocks * scale[:, :, None]).reshape(d_pad)


def async_step(
    fault: Any,
    buf: dict,
    u_vec: jax.Array,
    t: jax.Array,
    key: jax.Array,
    compression: Any = None,
):
    """One round of the stale-delta ring buffer.

    The round's aggregate ``u_vec`` (flattened, (D,)) is dispatched at round
    ``t`` with arrival round ``t + delay`` where ``delay`` derives from an
    in-trace latency sample quantized by ``round_time`` and clipped to
    ``B - 1`` — the clip guarantees a slot is always drained before the ring
    reuses it, so no pending delta is ever overwritten.  Every buffered delta
    whose arrival round has come is applied with a
    ``staleness_discount ** (t - dispatch)`` factor; ``delay == 0``
    degenerates to synchronous aggregation.

    With an enabled ``compression`` the written slot is quantized (blockwise,
    same scheme as the cohort buffer) and arrived rows are dequantized inside
    the discount contraction; ``apply_vec`` comes back (D,)-sliced so the
    caller is width-agnostic.

    Returns ``(new_buf, apply_vec, n_arrived)`` with ``apply_vec`` the (D,)
    staleness-discounted sum of arrived deltas for this round's server step.
    """
    b = int(fault.async_buffer)
    rho = jnp.float32(fault.staleness_discount)
    rt = _round_time(fault)
    t = jnp.asarray(t, jnp.int32)
    lat = latency_draw(fault, (), key)
    delay = jnp.clip(
        jnp.floor(lat / jnp.float32(rt)).astype(jnp.int32), 0, b - 1
    )
    slot = jnp.mod(t, b)
    d_dim = u_vec.shape[0]
    if compression is not None:
        from repro.kernels.fused_weighted_agg import quantize_stacked

        q_row, s_row = quantize_stacked(
            u_vec[None, :],
            dtype=compression.delta_dtype,
            scale_block=int(compression.scale_block),
        )
        delta = jax.lax.dynamic_update_index_in_dim(buf["delta"], q_row[0], slot, 0)
        scale = jax.lax.dynamic_update_index_in_dim(buf["scale"], s_row[0], slot, 0)
    else:
        delta = jax.lax.dynamic_update_index_in_dim(
            buf["delta"], u_vec.astype(jnp.float32), slot, 0
        )
    dispatch = buf["dispatch"].at[slot].set(t)
    arrival = buf["arrival"].at[slot].set(t + delay)
    valid = buf["valid"].at[slot].set(True)
    arrived = jnp.logical_and(valid, arrival <= t)
    disc = rho ** (t - dispatch).astype(jnp.float32)
    coef = jnp.where(arrived, disc, 0.0)
    if compression is not None:
        apply_vec = _ring_dequant_apply(buf, coef, delta=delta, scale=scale)[:d_dim]
        new_buf = {
            "delta": delta,
            "scale": scale,
            "dispatch": dispatch,
            "arrival": arrival,
            "valid": jnp.logical_and(valid, ~arrived),
        }
    else:
        apply_vec = coef @ delta  # (B,) @ (B, D) -> (D,)
        new_buf = {
            "delta": delta,
            "dispatch": dispatch,
            "arrival": arrival,
            "valid": jnp.logical_and(valid, ~arrived),
        }
    return new_buf, apply_vec, jnp.sum(arrived.astype(jnp.int32))


def flush_pending(buf: dict, t_end, rho: float) -> jax.Array:
    """Final-boundary flush: the staleness-discounted sum of every delta
    still pending when the horizon ends.  Mid-run segment boundaries leave
    the buffer intact in the carry (segmentation stays bitwise-neutral even
    in async mode); only the end of the horizon drains it, deterministically
    from the carried state — a resumed run flushes identically.  A quantized
    ring (``scale`` key present) is dequantized in the contraction; the
    result is then (D_pad,) and callers slice/unflatten to D."""
    t_end = jnp.asarray(t_end, jnp.int32)
    disc = jnp.float32(rho) ** (t_end - buf["dispatch"]).astype(jnp.float32)
    coef = jnp.where(buf["valid"], disc, 0.0)
    if "scale" in buf:
        return _ring_dequant_apply(buf, coef)
    return coef @ buf["delta"]


# ---------------------------------------------------------------------------
# Flattened-update helpers (the (B, D) buffer's D axis)
# ---------------------------------------------------------------------------


def flat_dim(tree) -> int:
    """Total element count of a pytree (works on ShapeDtypeStructs too)."""
    return int(
        sum(
            int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
            for leaf in jax.tree_util.tree_leaves(tree)
        )
    )


def tree_to_vec(tree) -> jax.Array:
    """Pytree of arrays -> one (D,) f32 vector (leaf-order concatenation)."""
    return jnp.concatenate(
        [jnp.ravel(leaf).astype(jnp.float32) for leaf in jax.tree_util.tree_leaves(tree)]
    )


def vec_to_tree(vec: jax.Array, like):
    """(D,) vector -> pytree shaped/dtyped like ``like`` (tree_to_vec inverse)."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
        out.append(vec[off : off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)
