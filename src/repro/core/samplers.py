"""Client samplers: K-Vib (Algorithm 2) and the paper's baselines.

Every sampler is a frozen configuration object with pure functions over an
explicit state pytree, so the whole sampling pipeline is jittable and can be
checkpointed alongside the model:

    sampler = KVib(n=N, budget=K, horizon=T)
    state   = sampler.init()
    probs   = sampler.probabilities(state)        # marginal inclusion probs
    draw    = sampler.sample(state, key)          # SampleResult
    state   = sampler.update(state, draw, feedback)

``feedback`` is the paper's ``pi_t(i) = lambda_i * ||g_i^t||`` for the clients
in the cohort (zeros elsewhere); the importance correction by the *sampling*
probability is done inside ``update`` (eq. under Theorem 5.2:
``omega(i) += pi_t^2(i) / p~_i``).

Two sampling procedures coexist (Section 2 of the paper):

* ISP — independent Bernoulli per client (``SampleResult.mask``); the
  estimator weight for client i is ``1/p_i``.
* RSP — K draws from a distribution over clients; we implement the
  with-replacement variant used by the online-variance-reduction baselines
  (Mabs, Vrb, Avare: one draw per step in their origin papers, K draws per
  round in the FL port) via ``SampleResult.counts`` and the without-
  replacement uniform variant used by vanilla FedAvg.

Serializable-state contract
---------------------------

Sampler state is part of the training state: it rides the compiled horizon's
scan carry (``repro.fed.state.TrainState``) and round-trips through
checkpoints (``repro.checkpoint``) at every segment boundary.  Both transports
impose the same rule, checked by ``assert_serializable_state`` and swept over
the whole registry in tests:

* the state is a pytree whose every leaf is an ARRAY (jax or numpy) — a
  Python int/float smuggled into the state would be baked into the trace as a
  constant (breaking the scan carry) and silently dropped from checkpoints;
* all dynamic quantities live in those arrays — the round counter is an int32
  *array* (``SamplerState.t``), not a Python attribute;
* static configuration (n, budget, horizon, cluster ids, ...) lives on the
  frozen ``Sampler`` dataclass, NOT in the state: restore is template-shaped,
  so config must be reconstructible without the checkpoint.

Any sampler obeying this contract can be preempted mid-horizon and resumed
bit-exactly from ``Sampler.init()`` as the restore template.

The dtype half of the contract: leaves must not be float64/complex128 (a
silent promotion doubles checkpoint size and breaks cross-platform bitwise
resume) and must not be weak-typed (numpy has no weak scalars, so a weak
leaf changes its aval across a checkpoint round trip and forces a recompile
on resume).  ``assert_serializable_state`` rejects both.

Scan-safety contract
--------------------

``Sampler.scan_safe_methods`` names the methods that ride the compiled
horizon's ``lax.scan`` body — ``probabilities`` / ``sample_from`` /
``update`` — and therefore must trace abstractly: no data-dependent Python
control flow, no host callbacks, static shapes only, and ``update`` must
return a state with exactly the input state's avals.  ``abstract_state()``
and ``abstract_draw()`` provide the ShapeDtypeStruct arguments the static
checker (``repro.analysis.lint.audit_scan_safety``) traces them with.

Sharded (N,)-axis contract
--------------------------

With ``shard=ShardSpec(...)`` every (N,)-shaped quantity a sampler touches —
probabilities, draw fields, cumulative statistics — is pinned to the spec's
mesh axis via in-trace sharding constraints (``shard_constrain``), and the
water-filling solve runs shard-locally (``solver.isp_probabilities(...,
shard=...)``): nothing replicated scales O(N) per device.  Two rules keep
this compatible with the serializable-state and compile-once contracts:

* constraints apply ONLY under a trace — eager values (``init()``, restored
  checkpoints) stay uncommitted, so the compiled segment runner controls
  placement at its own boundary (``fed.state.make_segment_fn``);
* ``abstract_state()`` annotates (N,)-leaf avals with the NamedSharding so
  the lint auditors (and restore templates) see the sharded layout.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver
from repro.launch.mesh import ShardSpec

__all__ = [
    "SampleResult",
    "Sampler",
    "UniformISP",
    "UniformRSP",
    "KVib",
    "Vrb",
    "Mabs",
    "Avare",
    "OptimalISP",
    "Osmd",
    "ClusteredKVib",
    "make_sampler",
    "sampler_names",
    "assert_serializable_state",
]


def assert_serializable_state(state) -> None:
    """Enforce the serializable-state contract (module docstring).

    Raises ``TypeError`` if any pytree leaf is not a (jax or numpy) array —
    i.e. if a Python scalar was smuggled into a carry — and ``ValueError`` on
    a leafless state (nothing to checkpoint means nothing survives resume).

    Also enforces the dtype half of the contract (module docstring): leaves
    must not be float64/complex128 and must not be weak-typed — both change
    the carry's avals across a checkpoint round trip (the dtype by doubling
    storage and breaking bitwise resume, the weak type by being erased on
    the numpy side), which the compile-once guard
    (``repro.analysis.lint.audit_compile_once``) would report as a
    resume-time recompile."""
    leaves = jax.tree_util.tree_leaves(state)
    if not leaves:
        raise ValueError("sampler state has no array leaves; nothing would survive a checkpoint round trip")
    for i, leaf in enumerate(leaves):
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            raise TypeError(
                f"sampler-state leaf {i} is {type(leaf).__name__}, not an array "
                "— Python scalars are baked into traces as constants and "
                "dropped from checkpoints (serializable-state contract)"
            )
        dtype = np.dtype(leaf.dtype)
        if dtype in (np.dtype(np.float64), np.dtype(np.complex128)):
            raise TypeError(
                f"sampler-state leaf {i} has dtype {dtype.name} — 64-bit "
                "float leaves double checkpoint size and break cross-platform "
                "bitwise resume (serializable-state dtype contract; see "
                "repro.analysis.lint audit_dtypes)"
            )
        if getattr(leaf, "weak_type", False):
            raise TypeError(
                f"sampler-state leaf {i} is weak-typed — weak types are "
                "erased by checkpoint round trips (numpy has no weak "
                "scalars), changing the carry avals and forcing a recompile "
                "on resume (serializable-state dtype contract)"
            )


class SampleResult(NamedTuple):
    """Outcome of one sampling step.

    mask:      (N,) bool — client included (ISP semantics / union for RSP).
    counts:    (N,) int32 — number of draws (RSP with replacement); for ISP
               this equals mask.astype(int).
    marginals: (N,) float — inclusion probability P(i in S) used by mask-form
               estimators (ISP) and diagnostics.
    draw_probs:(N,) float — per-draw distribution (sums to 1) for RSP-WR
               estimators; for ISP this is marginals / K (diagnostic only).
    """

    mask: jax.Array
    counts: jax.Array
    marginals: jax.Array
    draw_probs: jax.Array

    @property
    def size(self) -> jax.Array:
        return jnp.sum(self.counts)


def _isp_draw(key: jax.Array, marginals: jax.Array) -> SampleResult:
    mask = jax.random.uniform(key, marginals.shape) < marginals
    return SampleResult(
        mask=mask,
        counts=mask.astype(jnp.int32),
        marginals=marginals,
        draw_probs=marginals / jnp.maximum(jnp.sum(marginals), 1e-30),
    )


def _rsp_wr_draw(key: jax.Array, draw_probs: jax.Array, budget: int) -> SampleResult:
    """K draws with replacement from a normalized distribution."""
    n = draw_probs.shape[0]
    idx = jax.random.choice(key, n, shape=(budget,), p=draw_probs)
    counts = jnp.zeros((n,), jnp.int32).at[idx].add(1)
    mask = counts > 0
    marginals = 1.0 - (1.0 - draw_probs) ** budget
    return SampleResult(mask=mask, counts=counts, marginals=marginals, draw_probs=draw_probs)


def _rsp_wor_uniform_draw(key: jax.Array, n: int, budget: int) -> SampleResult:
    idx = jax.random.choice(key, n, shape=(budget,), replace=False)
    counts = jnp.zeros((n,), jnp.int32).at[idx].add(1)
    marginals = jnp.full((n,), budget / n)
    return SampleResult(
        mask=counts > 0,
        counts=counts,
        marginals=marginals,
        draw_probs=jnp.full((n,), 1.0 / n),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SamplerState:
    """Generic sampler state: cumulative statistics + round counter.

    Every field is an array (the round counter included) — see the module's
    "Serializable-state contract": this pytree is what rides the compiled
    scan carry and what checkpoints persist across preemptions."""

    stats: jax.Array  # (N,) cumulative (importance-weighted) squared feedback
    aux: jax.Array  # (N,) sampler-specific (e.g. Avare's latest estimates)
    t: jax.Array  # scalar int32 round counter

    def tree_flatten(self):
        return (self.stats, self.aux, self.t), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class Sampler:
    """Base: uniform-ISP behaviour; subclasses override the three hooks."""

    n: int
    budget: int
    procedure: str = "isp"  # "isp" | "rsp_wr" | "rsp_wor"
    shard: ShardSpec | None = None  # (N,)-axis mesh layout (module docstring)

    # The scan-safety contract (module docstring): these methods run inside
    # the compiled horizon's scan body and must trace abstractly with static
    # shapes, no host callbacks, and (for update) aval-stable state.  The
    # static checker ``repro.analysis.lint.audit_scan_safety`` traces exactly
    # this list; a subclass adding a scan-carried hook must extend it.
    scan_safe_methods: ClassVar[tuple] = ("probabilities", "sample_from", "update")

    def shard_constrain(self, x: jax.Array) -> jax.Array:
        """Pin a leading-(N,) value to the sampler's client-shard layout.

        Identity when unsharded — and identity on CONCRETE arrays even when
        sharded: an eager constraint would commit the array (jit input
        placement then differs between fresh and carried state, costing a
        recompile per segment), so placement of at-rest state belongs to the
        segment runner's boundary, not here."""
        if self.shard is None or not isinstance(x, jax.core.Tracer):
            return x
        return jax.lax.with_sharding_constraint(x, self.shard.named_sharding())

    def shard_state(self, state: SamplerState) -> SamplerState:
        """``shard_constrain`` over a state's (N,) leaves (t stays scalar)."""
        if self.shard is None:
            return state
        return SamplerState(
            stats=self.shard_constrain(state.stats),
            aux=self.shard_constrain(state.aux),
            t=state.t,
        )

    def abstract_state(self):
        """``init()``'s state as ShapeDtypeStructs (no arrays built) — the
        trace argument for the scan-safety checker and restore templates.
        With ``shard`` set, (N,)-leading leaves carry the NamedSharding so
        auditors see the sharded avals."""
        st = jax.eval_shape(self.init)
        if self.shard is None:
            return st
        ns = self.shard.named_sharding()

        def annotate(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == self.n:
                return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ns)
            return leaf

        return jax.tree_util.tree_map(annotate, st)

    def abstract_draw(self) -> SampleResult:
        """A ``SampleResult`` of ShapeDtypeStructs per the documented field
        contract — deliberately NOT derived by tracing ``sample`` (the
        checker must be able to lint ``update`` even when sampling itself is
        broken)."""
        f32 = jnp.float32
        return SampleResult(
            mask=jax.ShapeDtypeStruct((self.n,), jnp.bool_),
            counts=jax.ShapeDtypeStruct((self.n,), jnp.int32),
            marginals=jax.ShapeDtypeStruct((self.n,), f32),
            draw_probs=jax.ShapeDtypeStruct((self.n,), f32),
        )

    # -- hooks ---------------------------------------------------------------
    def init(self) -> SamplerState:
        # Deliberately NOT shard-constrained: init is eager and at-rest state
        # stays uncommitted (sharded-axis contract, module docstring).
        return SamplerState(
            stats=jnp.zeros((self.n,), jnp.float32),
            aux=jnp.zeros((self.n,), jnp.float32),
            t=jnp.zeros((), jnp.int32),
        )

    def probabilities(self, state: SamplerState) -> jax.Array:
        """Marginal inclusion probabilities (sum == budget for ISP)."""
        return self.shard_constrain(jnp.full((self.n,), self.budget / self.n))

    def sample_from(self, probs: jax.Array, key: jax.Array) -> SampleResult:
        """Draw a cohort from an already-solved probability vector.

        Splitting the solve (``probabilities``) from the draw lets callers —
        in particular the compiled server loop — compute p~ exactly once per
        round and reuse it for both the draw and the regret diagnostics.
        """
        probs = self.shard_constrain(probs)
        if self.procedure == "isp":
            res = _isp_draw(key, probs)
        elif self.procedure == "rsp_wr":
            res = _rsp_wr_draw(
                key, probs / jnp.maximum(jnp.sum(probs), 1e-30), self.budget
            )
        else:
            res = _rsp_wor_uniform_draw(key, self.n, self.budget)
        if self.shard is None:
            return res
        return SampleResult(*(self.shard_constrain(f) for f in res))

    def sample(self, state: SamplerState, key: jax.Array) -> SampleResult:
        return self.sample_from(self.probabilities(state), key)

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        return self.shard_state(dataclasses.replace(state, t=state.t + 1))


@dataclasses.dataclass(frozen=True)
class UniformISP(Sampler):
    """Independent Bernoulli(K/N) — the naive-ISP baseline of Section 3."""


@dataclasses.dataclass(frozen=True)
class UniformRSP(Sampler):
    """Vanilla FedAvg sampling: K uniform without replacement."""

    procedure: str = "rsp_wor"


@dataclasses.dataclass(frozen=True)
class KVib(Sampler):
    """Algorithm 2 — the paper's contribution.

    p^t from the FTRL water-filling solution on sqrt(omega + gamma)
    (Lemma 5.1), mixed with theta * K/N (eq. 12), drawn independently, and
    updated with importance-weighted squared feedback.

    Hyperparameters follow Section 6: theta = (N/(T K))^{1/3},
    gamma ~= G^2 N / (theta K) with G estimated from first-round feedback
    when ``gamma`` is left as None (``auto_gamma``).
    """

    horizon: int = 500
    theta: float | None = None
    gamma: float | None = None
    p_min: float = 0.0  # optional explicit floor below the mixing floor

    def _theta(self) -> float:
        if self.theta is not None:
            return float(self.theta)
        return float(min(1.0, (self.n / (self.horizon * self.budget)) ** (1.0 / 3.0)))

    def init(self) -> SamplerState:
        st = super().init()
        # aux[0] stores the running gamma (auto-estimated from first feedback);
        # keep one slot per client for pytree-shape uniformity, broadcast use.
        gamma0 = 0.0 if self.gamma is None else float(self.gamma)
        return dataclasses.replace(st, aux=jnp.full((self.n,), gamma0, jnp.float32))

    def probabilities(self, state: SamplerState) -> jax.Array:
        gamma = jnp.maximum(state.aux[0], 1e-12)
        scores = jnp.sqrt(self.shard_constrain(state.stats) + gamma)
        p = solver.isp_probabilities(
            scores, self.budget, p_min=self.p_min, shard=self.shard
        )
        return self.shard_constrain(
            solver.mix_probabilities(p, self._theta(), self.budget)
        )

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        p_used = draw.marginals
        contrib = jnp.where(
            draw.mask, feedback**2 / jnp.maximum(p_used, 1e-30), 0.0
        )
        stats = state.stats + contrib
        aux = state.aux
        if self.gamma is None:
            # First-round auto-gamma: G ~ mean of observed feedback (paper
            # Section 6 "FL and sampler hyperparameters").
            g_est = jnp.sum(jnp.where(draw.mask, feedback, 0.0)) / jnp.maximum(
                jnp.sum(draw.mask), 1
            )
            gamma_auto = g_est**2 * self.n / (self._theta() * self.budget)
            aux = jnp.where(state.t == 0, jnp.full_like(aux, gamma_auto), aux)
        return self.shard_state(SamplerState(stats=stats, aux=aux, t=state.t + 1))


@dataclasses.dataclass(frozen=True)
class Vrb(Sampler):
    """Variance-Reducer-Bandit (Borsos et al., 2018) — RSP baseline.

    FTRL on the probability *simplex*: p_i ~ sqrt(cumulative squared feedback
    + gamma), mixed with theta-uniform, K draws with replacement.
    """

    procedure: str = "rsp_wr"
    horizon: int = 500
    theta: float | None = None
    gamma: float | None = None

    def _theta(self) -> float:
        if self.theta is not None:
            return float(self.theta)
        return float(min(1.0, (self.n / self.horizon) ** (1.0 / 3.0)))

    def init(self) -> SamplerState:
        st = super().init()
        gamma0 = 0.0 if self.gamma is None else float(self.gamma)
        return dataclasses.replace(st, aux=jnp.full((self.n,), gamma0, jnp.float32))

    def probabilities(self, state: SamplerState) -> jax.Array:
        gamma = jnp.maximum(state.aux[0], 1e-12)
        w = jnp.sqrt(self.shard_constrain(state.stats) + gamma)
        p = w / jnp.maximum(jnp.sum(w), 1e-30)
        theta = self._theta()
        return self.shard_constrain((1.0 - theta) * p + theta / self.n)

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        # Importance-weight against the per-draw probability; each draw of i
        # contributes feedback^2 / q_i (counts-aware).
        q = jnp.maximum(draw.draw_probs, 1e-30)
        contrib = draw.counts.astype(feedback.dtype) * feedback**2 / q
        stats = state.stats + contrib / jnp.maximum(self.budget, 1)
        aux = state.aux
        if self.gamma is None:
            g_est = jnp.sum(jnp.where(draw.mask, feedback, 0.0)) / jnp.maximum(
                jnp.sum(draw.mask), 1
            )
            gamma_auto = g_est**2 * self.n / jnp.maximum(self._theta(), 1e-6)
            aux = jnp.where(state.t == 0, jnp.full_like(aux, gamma_auto), aux)
        return self.shard_state(SamplerState(stats=stats, aux=aux, t=state.t + 1))


@dataclasses.dataclass(frozen=True)
class Mabs(Sampler):
    """Multi-armed-bandit sampler (Salehi et al., 2017) — EXP3-style RSP.

    Multiplicative-weights on importance-weighted squared feedback with a
    stability stepsize eta (0.4 per the original paper), theta-uniform mixing.
    """

    procedure: str = "rsp_wr"
    eta: float = 0.4
    theta: float = 0.1

    def probabilities(self, state: SamplerState) -> jax.Array:
        logw = self.shard_constrain(state.stats) - jnp.max(state.stats)
        w = jnp.exp(logw)
        p = w / jnp.maximum(jnp.sum(w), 1e-30)
        return self.shard_constrain((1.0 - self.theta) * p + self.theta / self.n)

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        q = jnp.maximum(draw.draw_probs, 1e-30)
        # Normalized reward in [0, ~1] per draw for EXP3 stability.
        fb2 = feedback**2
        scale = jnp.maximum(jnp.max(jnp.where(draw.mask, fb2, 0.0)), 1e-30)
        reward = draw.counts.astype(feedback.dtype) * (fb2 / scale) / q
        stats = state.stats + self.eta * reward / jnp.maximum(self.budget, 1) / self.n
        return self.shard_state(SamplerState(stats=stats, aux=state.aux, t=state.t + 1))


@dataclasses.dataclass(frozen=True)
class Avare(Sampler):
    """Avare (El Hanchi & Stephens, 2020) — RSP baseline.

    Maintains a per-client estimate of the latest feedback magnitude (their
    ``a_i`` upper-confidence estimates with decreasing stepsizes); samples
    proportionally with a probability floor p_min = 1/(5N).
    """

    procedure: str = "rsp_wr"
    p_min_frac: float = 0.2  # p_min = p_min_frac / N

    def init(self) -> SamplerState:
        st = super().init()
        # Optimistic initialization so unexplored clients keep getting drawn.
        return dataclasses.replace(st, aux=jnp.full((self.n,), jnp.inf, jnp.float32))

    def probabilities(self, state: SamplerState) -> jax.Array:
        est = jnp.where(jnp.isfinite(state.aux), state.aux, 0.0)
        explored = jnp.isfinite(state.aux)
        # Unexplored clients get the max observed estimate (optimism).
        opt = jnp.where(
            explored, est, jnp.max(jnp.where(explored, est, 0.0)) + 1e-6
        )
        opt = jnp.where(jnp.any(explored), opt, jnp.ones_like(opt))
        p = opt / jnp.maximum(jnp.sum(opt), 1e-30)
        p_min = self.p_min_frac / self.n
        p = jnp.maximum(p, p_min)
        return self.shard_constrain(p / jnp.sum(p))

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        # Latest-value estimate for sampled clients (constant stepsize delta=1).
        aux = jnp.where(draw.mask, feedback, state.aux)
        return self.shard_state(SamplerState(stats=state.stats, aux=aux, t=state.t + 1))


@dataclasses.dataclass(frozen=True)
class OptimalISP(Sampler):
    """Oracle (Lemma 2.2): needs the *current* full feedback — diagnostics only.

    ``update`` stores the full feedback vector; ``probabilities`` water-fills
    it. The FL server cannot run this without full participation; we use it to
    measure sampling quality Q(S^t) and the beta_1/beta_2 terms of Thm 4.1.
    """

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        return self.shard_state(SamplerState(stats=feedback, aux=state.aux, t=state.t + 1))

    def probabilities(self, state: SamplerState) -> jax.Array:
        has_fb = jnp.any(state.stats > 0)
        p_opt = solver.isp_probabilities(state.stats, self.budget, shard=self.shard)
        return self.shard_constrain(
            jnp.where(has_fb, p_opt, jnp.full((self.n,), self.budget / self.n))
        )


@dataclasses.dataclass(frozen=True)
class Osmd(Sampler):
    """OSMD-style sampler (Zhao et al. 2021, paper Appendix E.3).

    Online stochastic mirror descent on the sampling distribution with the
    importance-weighted squared-feedback loss gradient — the paper's
    discussion point: OSMD keeps the RSP procedure and replaces the mixing
    strategy with a mirror-descent update; our ISP findings are orthogonal
    and could be composed with it.  Implemented as an RSP baseline: one
    mirror step per round on the negative-entropy geometry (multiplicative
    update + simplex projection with a floor).
    """

    procedure: str = "rsp_wr"
    lr: float = 0.5
    p_min_frac: float = 0.2  # floor = p_min_frac / N

    def init(self) -> SamplerState:
        st = super().init()
        return dataclasses.replace(
            st, stats=jnp.full((self.n,), 1.0 / self.n, jnp.float32)
        )

    def probabilities(self, state: SamplerState) -> jax.Array:
        return self.shard_constrain(state.stats)

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        p = state.stats
        q = jnp.maximum(draw.draw_probs, 1e-30)
        # grad of E[pi^2/p] wrt p at sampled points: -pi^2/p^2 (importance wt)
        grad = -draw.counts.astype(jnp.float32) * feedback**2 / (q * p**2)
        grad = grad / jnp.maximum(self.budget, 1)
        # normalized mirror step: p <- p * exp(-lr * grad / scale)
        scale = jnp.maximum(jnp.max(jnp.abs(grad)), 1e-30)
        logp = jnp.log(p) - self.lr * grad / scale
        p_new = jax.nn.softmax(logp)
        floor = self.p_min_frac / self.n
        p_new = jnp.maximum(p_new, floor)
        p_new = p_new / jnp.sum(p_new)
        return self.shard_state(SamplerState(stats=p_new, aux=state.aux, t=state.t + 1))


@dataclasses.dataclass(frozen=True)
class ClusteredKVib(Sampler):
    """Cluster-aware K-Vib (paper Section 7: 'unstable local feedback ...
    can be addressed with client clustering', cf. Fraboni et al. 2021).

    Clients are partitioned into m clusters (e.g. by data size or domain);
    the FTRL statistics are pooled *within clusters*, so a client inherits
    its cluster's feedback history even before being sampled — faster
    exploration when clients within a cluster are statistically exchangeable.
    The sampling itself stays independent per client (ISP, unbiased as ever).
    """

    cluster_ids: tuple = ()  # len n, values in [0, m); empty = every client alone
    horizon: int = 500
    theta: float | None = None
    gamma: float | None = None

    def _theta(self) -> float:
        if self.theta is not None:
            return float(self.theta)
        return float(min(1.0, (self.n / (self.horizon * self.budget)) ** (1.0 / 3.0)))

    def init(self) -> SamplerState:
        st = super().init()
        gamma0 = 0.0 if self.gamma is None else float(self.gamma)
        return dataclasses.replace(st, aux=jnp.full((self.n,), gamma0, jnp.float32))

    def _cluster_mean_stats(self, stats: jax.Array) -> jax.Array:
        # cluster_ids is static config, so the segment count m is a Python int
        # and every shape below is known at trace time (scan/jit safe).
        if not self.cluster_ids:
            return stats  # degenerate clustering: vanilla K-Vib statistics
        cid = jnp.asarray(self.cluster_ids, jnp.int32)
        m = int(max(self.cluster_ids)) + 1
        sums = jnp.zeros((m,), jnp.float32).at[cid].add(stats)
        cnts = jnp.zeros((m,), jnp.float32).at[cid].add(1.0)
        return (sums / jnp.maximum(cnts, 1.0))[cid]

    def probabilities(self, state: SamplerState) -> jax.Array:
        gamma = jnp.maximum(state.aux[0], 1e-12)
        pooled = self._cluster_mean_stats(self.shard_constrain(state.stats))
        scores = jnp.sqrt(pooled + gamma)
        p = solver.isp_probabilities(scores, self.budget, shard=self.shard)
        return self.shard_constrain(
            solver.mix_probabilities(p, self._theta(), self.budget)
        )

    def update(
        self, state: SamplerState, draw: SampleResult, feedback: jax.Array
    ) -> SamplerState:
        contrib = jnp.where(
            draw.mask, feedback**2 / jnp.maximum(draw.marginals, 1e-30), 0.0
        )
        stats = state.stats + contrib
        aux = state.aux
        if self.gamma is None:
            g_est = jnp.sum(jnp.where(draw.mask, feedback, 0.0)) / jnp.maximum(
                jnp.sum(draw.mask), 1
            )
            gamma_auto = g_est**2 * self.n / (self._theta() * self.budget)
            aux = jnp.where(state.t == 0, jnp.full_like(aux, gamma_auto), aux)
        return self.shard_state(SamplerState(stats=stats, aux=aux, t=state.t + 1))


_REGISTRY = {
    "uniform_isp": UniformISP,
    "uniform_rsp": UniformRSP,
    "kvib": KVib,
    "vrb": Vrb,
    "mabs": Mabs,
    "avare": Avare,
    "optimal_isp": OptimalISP,
    "osmd": Osmd,
    "clustered_kvib": ClusteredKVib,
}


def make_sampler(name: str, n: int, budget: int, **kw) -> Sampler:
    try:
        cls = _REGISTRY[name]
    except KeyError as e:
        raise ValueError(f"unknown sampler {name!r}; options: {sorted(_REGISTRY)}") from e
    return cls(n=n, budget=budget, **kw)


def sampler_names() -> list[str]:
    """Registry names accepted by ``make_sampler`` (and by
    ``repro.api.SamplerSpec.name`` / the launcher's ``--sampler`` flag)."""
    return sorted(_REGISTRY)
