"""Budgeted water-filling solvers for independent-sampling probabilities.

This module implements the closed-form solutions of the paper:

* Lemma 2.2 (ISP): ``min_p sum_i a_i^2 / p_i`` subject to ``sum_i p_i = K``,
  ``0 < p_i <= 1`` — the optimal independent-sampling probabilities given
  scores ``a_i = lambda_i * ||g_i||``.
* Lemma 5.1 / Lemma B.8: the same program with an additional floor
  ``p_i >= p_min`` (the FTRL solution with regularizer gamma uses
  ``a_i = sqrt(pi^2_{1:t-1}(i) + gamma)``).
* Lemma 2.2 (RSP): ``p_i = K * a_i / sum_j a_j`` (probabilities for the
  random-sampling procedure; minimizes the *loose* RSP variance bound).

TPU adaptation note (DESIGN.md section 3): the paper's Appendix G maintains an
incrementally sorted list with binary-search insertion — a serial-CPU idiom.
Here the KKT system is solved *vectorized*: the stationarity condition gives
``p_i = clip(a_i / s, p_min, 1)`` for a single scalar water level ``s`` chosen
so that ``sum_i p_i = K``.  ``f(s) = sum_i clip(a_i/s, p_min, 1)`` is monotone
non-increasing in ``s``, so the level is found by monotone bisection (fixed
iteration count => jittable, O(N) per iteration) and then *snapped* to the
exact rational solution on the identified middle segment, recovering the
closed form of Lemma B.8 to machine precision.  O(N) per solve on device,
O(N log N) overall with the sort-free formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "isp_probabilities",
    "rsp_probabilities",
    "mix_probabilities",
    "expected_cost",
    "optimal_cost",
]

@functools.partial(jax.jit, static_argnames=())
def _isp_solve(a: jax.Array, budget: jax.Array, p_min: jax.Array) -> jax.Array:
    """Solve min sum a_i^2/p_i s.t. sum p = budget, p_min <= p <= 1.

    Exact breakpoint search: the KKT solution is p_i = clip(a_i/s, p_min, 1)
    for a scalar water level s.  f(s) = sum_i clip(a_i/s, p_min, 1) is
    monotone non-increasing and piecewise-hyperbolic with breakpoints at
    s = a_i (cap boundary) and s = a_i / p_min (floor boundary).  We evaluate
    f at all 2N breakpoints via sorted prefix sums (O(N log N)), locate the
    segment bracketing the budget, and solve the segment's closed form
    s* = c / z with c = sum of middle scores, z = budget - |U| - |L| p_min —
    exactly Lemma B.8.

    Requires a_i > 0 (callers add the gamma regularizer), 0 < p_min <= budget/N.
    """
    a = jnp.asarray(a)
    n = a.shape[0]

    a_sorted = jnp.sort(a)
    prefix = jnp.concatenate([jnp.zeros((1,), a.dtype), jnp.cumsum(a_sorted)])

    def f_and_sets(s):
        # |L| = #{a_i <= s*p_min}; |U| = #{a_i >= s}; middle sum via prefix.
        n_lower = jnp.searchsorted(a_sorted, s * p_min, side="right")
        n_not_upper = jnp.searchsorted(a_sorted, s, side="left")
        n_upper = n - n_not_upper
        c = prefix[n_not_upper] - prefix[n_lower]
        f = n_upper + n_lower * p_min + c / s
        return f, n_lower, n_upper, c

    # Candidate breakpoints (strictly positive).
    bps = jnp.sort(jnp.concatenate([a_sorted, a_sorted / p_min]))
    f_at_bps = jax.vmap(lambda s: f_and_sets(s)[0])(bps)
    # f_at_bps is non-increasing along bps.  Find the last breakpoint with
    # f >= budget: the solution lies in [bps[j], bps[j+1]].
    ge = f_at_bps >= budget
    j = jnp.maximum(jnp.sum(ge) - 1, 0)
    lo = bps[j]
    hi = bps[jnp.minimum(j + 1, 2 * n - 1)]
    s_probe = 0.5 * (lo + hi)
    # Within the open segment the active sets are fixed; recover them at the
    # midpoint and solve the closed form.
    _, n_lower, n_upper, c = f_and_sets(s_probe)
    z = budget - n_upper - n_lower * p_min
    s_star = jnp.where(z > 0, c / jnp.maximum(z, 1e-30), lo)
    # Degenerate: budget >= N -> everything saturates at 1.
    p = jnp.clip(a / jnp.maximum(s_star, 1e-30), p_min, 1.0)
    p = jnp.where(budget >= n, jnp.ones_like(p), p)
    return p


def isp_probabilities(
    scores: jax.Array, budget: float | jax.Array, p_min: float | jax.Array = 0.0
) -> jax.Array:
    """Optimal independent-sampling probabilities (Lemma 2.2 / Lemma 5.1).

    Args:
      scores: non-negative per-client scores ``a_i`` (e.g. ``lambda_i*||g_i||``
        for Lemma 2.2, ``sqrt(pi^2_{1:t-1}(i) + gamma)`` for the FTRL solution).
      budget: expected cohort size ``K`` with ``0 < K <= N``.
      p_min: probability floor (0 recovers Lemma 2.2; the paper requires
        ``p_min <= K/(2N)`` in the analysis).

    Returns:
      p with ``p_min <= p_i <= 1`` and ``sum(p) == K`` (to float tolerance).
    """
    scores = jnp.asarray(scores)
    n = scores.shape[0]
    budget = jnp.asarray(budget, dtype=scores.dtype)
    # A zero floor breaks the bisection bracket; use a tiny positive floor and
    # rely on snapping (clients with a_i == 0 get p = floor ~ 0, matching the
    # open-constraint solution p_i -> 0+).
    eps_floor = jnp.asarray(1e-12, scores.dtype)
    p_min_arr = jnp.maximum(jnp.asarray(p_min, dtype=scores.dtype), eps_floor)
    # Strictly positive scores for the solver; zero-score clients sit at floor.
    safe = jnp.maximum(scores, 1e-30)
    p = _isp_solve(safe, budget, p_min_arr)
    return p


def rsp_probabilities(scores: jax.Array, budget: float | jax.Array) -> jax.Array:
    """Optimal marginals for the random sampling procedure: K * a / sum(a).

    Clipped to 1 with iterative mass redistribution so the result stays a
    valid marginal vector when K * max(a) > sum(a)  (the paper assumes the
    non-degenerate regime; production code must not produce p > 1).
    """
    scores = jnp.asarray(scores)
    budget = jnp.asarray(budget, dtype=scores.dtype)

    def body(_, p_and_free):
        # redistribute: clients at cap 1 keep it; remaining budget spread
        # proportionally over free clients.
        p, _ = p_and_free
        capped = p >= 1.0
        k_rem = budget - jnp.sum(capped)
        denom = jnp.sum(jnp.where(capped, 0.0, scores))
        p_new = jnp.where(
            capped, 1.0, k_rem * scores / jnp.maximum(denom, 1e-30)
        )
        return p_new, capped

    total = jnp.maximum(jnp.sum(scores), 1e-30)
    p0 = budget * scores / total
    # N iterations suffice in the worst case; a handful in practice.
    p, _ = jax.lax.fori_loop(
        0, 8, body, (p0, jnp.zeros_like(p0, dtype=bool))
    )
    return jnp.clip(p, 0.0, 1.0)


def mix_probabilities(p: jax.Array, theta: float | jax.Array, budget: float | jax.Array) -> jax.Array:
    """Mixing strategy, eq. (12): p~ = (1-theta) p + theta * K/N."""
    p = jnp.asarray(p)
    n = p.shape[0]
    theta = jnp.asarray(theta, p.dtype)
    budget = jnp.asarray(budget, p.dtype)
    return (1.0 - theta) * p + theta * budget / n


def expected_cost(scores: jax.Array, p: jax.Array) -> jax.Array:
    """Online cost l_t(p) = sum_i a_i^2 / p_i (Section 5.1)."""
    scores = jnp.asarray(scores)
    p = jnp.asarray(p)
    return jnp.sum(jnp.where(scores > 0, scores**2 / jnp.maximum(p, 1e-30), 0.0))


def optimal_cost(scores: jax.Array, budget: float | jax.Array) -> jax.Array:
    """min_p l_t(p) over the ISP polytope — used by regret metrics.

    Closed form when no p saturates: (sum a)^2 / K (eq. 39); in general we
    evaluate the cost at the exact solver output.
    """
    p_star = isp_probabilities(scores, budget, p_min=0.0)
    return expected_cost(scores, p_star)
