"""Budgeted water-filling solvers for independent-sampling probabilities.

This module implements the closed-form solutions of the paper:

* Lemma 2.2 (ISP): ``min_p sum_i a_i^2 / p_i`` subject to ``sum_i p_i = K``,
  ``0 < p_i <= 1`` — the optimal independent-sampling probabilities given
  scores ``a_i = lambda_i * ||g_i||``.
* Lemma 5.1 / Lemma B.8: the same program with an additional floor
  ``p_i >= p_min`` (the FTRL solution with regularizer gamma uses
  ``a_i = sqrt(pi^2_{1:t-1}(i) + gamma)``).
* Lemma 2.2 (RSP): ``p_i = K * a_i / sum_j a_j`` (probabilities for the
  random-sampling procedure; minimizes the *loose* RSP variance bound).

TPU adaptation note (DESIGN.md section 3): the paper's Appendix G maintains an
incrementally sorted list with binary-search insertion — a serial-CPU idiom.
Here the KKT system is solved *vectorized*: the stationarity condition gives
``p_i = clip(a_i / s, p_min, 1)`` for a single scalar water level ``s`` chosen
so that ``sum_i p_i = K``.  ``f(s) = sum_i clip(a_i/s, p_min, 1)`` is monotone
non-increasing in ``s``, so the level is found by breakpoint search over the
sorted scores and then *snapped* to the exact rational solution on the
identified middle segment, recovering the closed form of Lemma B.8 to machine
precision.

Two solve paths share that snap:

* **Single-device** (``_isp_solve``): evaluate f at all 2N breakpoints
  ``{a_i, a_i/p_min}`` via sorted prefix sums (O(N log N)) and bracket the
  budget crossing directly.
* **Sharded** (``shard=ShardSpec(...)``): nothing replicated scales O(N).
  Each mesh shard sorts and prefix-sums only its own (N/S,) slice; the
  crossing is bracketed by a fixed-depth threshold search in log-space
  (``lax.scan`` bisection, or on TPU the ``kernels/sharded_waterfill``
  Pallas segmented scan that scores a 128-level ladder per pass) whose
  per-shard counting statistics are merged with one ``psum`` per step.  The
  final level is snapped by recomputing the active sets from the *local
  sorted prefix sums* — the same searchsorted/prefix-difference expressions
  as the single-device path — so on one shard the result is **bitwise equal**
  to ``_isp_solve``, and across S>1 shards it differs only by the psum
  reassociation of the middle-set score sum (documented eps, ~1e-6 relative).
  Shard-count padding uses +inf scores, which sit above every finite
  threshold and therefore never enter a count or sum.

Host-path input validation (concrete arrays only): ``isp_probabilities``
raises ``ValueError`` for ``budget`` outside ``(0, N]``, ``p_min`` outside
``[0, budget/N]``, or negative / non-finite scores.  Under a trace these
checks are unreachable (values are abstract); the traced path instead clips —
scores through ``max(a, 1e-30)``, the floor through
``max(p_min, 1e-12)``, and ``budget >= N`` through full saturation — so a
compiled training step never faults, it degrades to the nearest feasible
program.  Zero scores are always legal: those clients sit at the floor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "isp_probabilities",
    "rsp_probabilities",
    "mix_probabilities",
    "expected_cost",
    "optimal_cost",
]


def _validate_solver_inputs(scores, budget, p_min) -> None:
    """Host-path guard: raise on infeasible inputs instead of silently
    returning garbage.  No-op under tracing (abstract values can't be
    inspected — the traced path clips; see module docstring)."""
    if any(
        isinstance(x, jax.core.Tracer) for x in (scores, budget, p_min)
    ):
        return
    import numpy as np

    n = scores.shape[0]
    b = float(budget)
    pm = float(p_min)
    if not 0.0 < b <= n:
        raise ValueError(
            f"budget must satisfy 0 < budget <= N; got budget={b} with N={n}"
        )
    if pm < 0.0 or pm > b / n * (1.0 + 1e-6):
        raise ValueError(
            f"p_min must satisfy 0 <= p_min <= budget/N = {b / n:.6g}; "
            f"got p_min={pm} (the paper's regime is p_min <= K/(2N))"
        )
    s = np.asarray(scores)
    if not np.all(np.isfinite(s)):
        raise ValueError("scores must be finite (got NaN or inf)")
    if np.any(s < 0):
        raise ValueError(
            f"scores must be non-negative; min score = {float(s.min())} "
            "(zero scores are legal: those clients sit at the floor)"
        )

@functools.partial(jax.jit, static_argnames=())
def _isp_solve(a: jax.Array, budget: jax.Array, p_min: jax.Array) -> jax.Array:
    """Solve min sum a_i^2/p_i s.t. sum p = budget, p_min <= p <= 1.

    Exact breakpoint search: the KKT solution is p_i = clip(a_i/s, p_min, 1)
    for a scalar water level s.  f(s) = sum_i clip(a_i/s, p_min, 1) is
    monotone non-increasing and piecewise-hyperbolic with breakpoints at
    s = a_i (cap boundary) and s = a_i / p_min (floor boundary).  We evaluate
    f at all 2N breakpoints via sorted prefix sums (O(N log N)), locate the
    segment bracketing the budget, and solve the segment's closed form
    s* = c / z with c = sum of middle scores, z = budget - |U| - |L| p_min —
    exactly Lemma B.8.

    Requires a_i > 0 (callers add the gamma regularizer), 0 < p_min <= budget/N.
    """
    a = jnp.asarray(a)
    n = a.shape[0]

    a_sorted = jnp.sort(a)
    prefix = jnp.concatenate([jnp.zeros((1,), a.dtype), jnp.cumsum(a_sorted)])

    def f_and_sets(s):
        # |L| = #{a_i <= s*p_min}; |U| = #{a_i >= s}; middle sum via prefix.
        n_lower = jnp.searchsorted(a_sorted, s * p_min, side="right")
        n_not_upper = jnp.searchsorted(a_sorted, s, side="left")
        n_upper = n - n_not_upper
        c = prefix[n_not_upper] - prefix[n_lower]
        f = n_upper + n_lower * p_min + c / s
        return f, n_lower, n_upper, c

    # Candidate breakpoints (strictly positive).
    bps = jnp.sort(jnp.concatenate([a_sorted, a_sorted / p_min]))
    f_at_bps = jax.vmap(lambda s: f_and_sets(s)[0])(bps)
    # f_at_bps is non-increasing along bps.  Find the last breakpoint with
    # f >= budget: the solution lies in [bps[j], bps[j+1]].
    ge = f_at_bps >= budget
    j = jnp.maximum(jnp.sum(ge) - 1, 0)
    lo = bps[j]
    hi = bps[jnp.minimum(j + 1, 2 * n - 1)]
    s_probe = 0.5 * (lo + hi)
    # Within the open segment the active sets are fixed; recover them at the
    # midpoint and solve the closed form.
    _, n_lower, n_upper, c = f_and_sets(s_probe)
    z = budget - n_upper - n_lower * p_min
    s_star = jnp.where(z > 0, c / jnp.maximum(z, 1e-30), lo)
    # Degenerate: budget >= N -> everything saturates at 1.
    p = jnp.clip(a / jnp.maximum(s_star, 1e-30), p_min, 1.0)
    p = jnp.where(budget >= n, jnp.ones_like(p), p)
    return p


def _isp_solve_local(
    a_local: jax.Array,
    budget: jax.Array,
    p_min: jax.Array,
    *,
    n_global: int,
    axis_name: str | None = None,
    bisect_depth: int = 64,
    use_kernel: bool = False,
    kernel_rounds: int = 5,
    interpret: bool = True,
) -> jax.Array:
    """Shard-local body of the sharded water-filling solve.

    Runs under ``shard_map`` when ``axis_name`` is set (one psum/pmin/pmax
    per search step merges the per-shard statistics); with ``axis_name=None``
    it degenerates to a single-shard O(N) solve.  ``a_local`` may carry +inf
    padding (shard-count remainder): infs sort last, sit above every finite
    threshold, and clip to p=1 entries the caller slices off.

    The budget crossing of f(s) = sum clip(a_i/s, p_min, 1) is bracketed in
    log-space — ``bisect_depth`` scan steps of geometric bisection, or with
    ``use_kernel`` a ``kernel_rounds``-deep refinement that scores a
    128-level geometric ladder per pass with the Pallas segmented-scan
    kernel.  The bracket is then snapped to the exact Lemma B.8 rational
    solution via the same local sorted-prefix expressions as ``_isp_solve``,
    which is what makes the single-shard result bitwise-equal to it.
    """
    a_sorted = jnp.sort(a_local)
    prefix = jnp.concatenate(
        [jnp.zeros((1,), a_sorted.dtype), jnp.cumsum(a_sorted)]
    )

    def allsum(x):
        return jax.lax.psum(x, axis_name) if axis_name is not None else x

    finite = jnp.isfinite(a_sorted)
    a_min = jnp.min(jnp.where(finite, a_sorted, jnp.inf))
    a_max = jnp.max(jnp.where(finite, a_sorted, -jnp.inf))
    if axis_name is not None:
        a_min = jax.lax.pmin(a_min, axis_name)
        a_max = jax.lax.pmax(a_max, axis_name)

    def global_sets(s):
        # Same expressions as _isp_solve.f_and_sets, on the LOCAL sorted
        # prefix; psum merges the per-shard integer counts and middle sums.
        n_floor_l = jnp.searchsorted(a_sorted, s * p_min, side="right")
        n_below_l = jnp.searchsorted(a_sorted, s, side="left")
        c_l = prefix[n_below_l] - prefix[n_floor_l]
        return allsum(n_floor_l), n_global - allsum(n_below_l), allsum(c_l)

    # Bracket [lo0, hi0] strictly encloses every breakpoint {a_i, a_i/p_min}:
    # f(lo0) = N >= budget, f(hi0) = N*p_min <= budget.
    log_lo = jnp.log2(0.5 * a_min)
    log_hi = jnp.log2(2.0 * a_max / p_min)

    if use_kernel:
        from repro.kernels.sharded_waterfill import waterfill_level_stats

        n_levels = 128
        t = jnp.arange(n_levels, dtype=a_sorted.dtype) / (n_levels - 1)

        def ladder_round(carry, _):
            llo, lhi = carry
            logs = llo + t * (lhi - llo)
            levels = jnp.exp2(logs)
            n_below, n_floor, mid = waterfill_level_stats(
                a_sorted, levels, levels * p_min, interpret=interpret
            )
            f = (
                (n_global - allsum(n_below))
                + allsum(n_floor) * p_min
                + allsum(mid) / levels
            )
            j = jnp.maximum(jnp.sum(f >= budget) - 1, 0)
            return (logs[j], logs[jnp.minimum(j + 1, n_levels - 1)]), None

        (log_lo, log_hi), _ = jax.lax.scan(
            ladder_round, (log_lo, log_hi), None, length=kernel_rounds
        )
    else:

        def bisect(carry, _):
            llo, lhi = carry
            lmid = 0.5 * (llo + lhi)
            n_floor, n_upper, c = global_sets(jnp.exp2(lmid))
            ge = n_upper + n_floor * p_min + c / jnp.exp2(lmid) >= budget
            return (
                jnp.where(ge, lmid, llo),
                jnp.where(ge, lhi, lmid),
            ), None

        (log_lo, log_hi), _ = jax.lax.scan(
            bisect, (log_lo, log_hi), None, length=bisect_depth
        )

    # Snap: inside the bracketed open segment the active sets are fixed;
    # recover them at the (log-)midpoint and solve the Lemma B.8 closed form.
    s_probe = jnp.exp2(0.5 * (log_lo + log_hi))
    n_floor, n_upper, c = global_sets(s_probe)
    z = budget - n_upper - n_floor * p_min
    s_star = jnp.where(z > 0, c / jnp.maximum(z, 1e-30), jnp.exp2(log_lo))
    p = jnp.clip(a_local / jnp.maximum(s_star, 1e-30), p_min, 1.0)
    return jnp.where(budget >= n_global, jnp.ones_like(p), p)


@functools.partial(
    jax.jit, static_argnames=("shard", "use_kernel", "interpret")
)
def _isp_solve_sharded(
    a: jax.Array,
    budget: jax.Array,
    p_min: jax.Array,
    shard,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Solve over a (N,) score vector split across ``shard.axis`` of the
    ``shard`` (a launch.mesh.ShardSpec) mesh.  See _isp_solve_local."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    n = a.shape[0]
    pad = (-n) % shard.num_shards
    a_pad = (
        jnp.concatenate([a, jnp.full((pad,), jnp.inf, a.dtype)]) if pad else a
    )
    spec = PartitionSpec(shard.axis)
    fn = shard_map(
        functools.partial(
            _isp_solve_local,
            n_global=n,
            axis_name=shard.axis,
            use_kernel=use_kernel,
            interpret=interpret,
        ),
        mesh=shard.mesh(),
        in_specs=(spec, PartitionSpec(), PartitionSpec()),
        out_specs=spec,
        check_rep=False,
    )
    p = fn(a_pad, budget, p_min)
    return p[:n] if pad else p


def isp_probabilities(
    scores: jax.Array,
    budget: float | jax.Array,
    p_min: float | jax.Array = 0.0,
    *,
    shard=None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Optimal independent-sampling probabilities (Lemma 2.2 / Lemma 5.1).

    Args:
      scores: non-negative per-client scores ``a_i`` (e.g. ``lambda_i*||g_i||``
        for Lemma 2.2, ``sqrt(pi^2_{1:t-1}(i) + gamma)`` for the FTRL solution).
      budget: expected cohort size ``K`` with ``0 < K <= N``.
      p_min: probability floor (0 recovers Lemma 2.2; the paper requires
        ``p_min <= K/(2N)`` in the analysis).
      shard: optional ``launch.mesh.ShardSpec`` — solve with the (N,) axis
        split over that mesh axis (nothing replicated scales O(N)).  Bitwise
        equal to the unsharded solve on one shard; documented-eps on more
        (see module docstring).
      use_kernel: route the sharded threshold search through the Pallas
        ``sharded_waterfill`` kernel.  Default (None): on for TPU backends,
        off elsewhere (interpret-mode Pallas unrolls the chunk grid at trace
        time, which is the wrong trade on CPU).

    Returns:
      p with ``p_min <= p_i <= 1`` and ``sum(p) == K`` (to float tolerance).

    Raises:
      ValueError: on the host path (concrete inputs) for budget outside
        (0, N], p_min > budget/N, or negative / non-finite scores.  The
        traced path clips instead (module docstring).
    """
    scores = jnp.asarray(scores)
    n = scores.shape[0]
    _validate_solver_inputs(scores, budget, p_min)
    budget = jnp.asarray(budget, dtype=scores.dtype)
    # A zero floor breaks the bisection bracket; use a tiny positive floor and
    # rely on snapping (clients with a_i == 0 get p = floor ~ 0, matching the
    # open-constraint solution p_i -> 0+).
    eps_floor = jnp.asarray(1e-12, scores.dtype)
    p_min_arr = jnp.maximum(jnp.asarray(p_min, dtype=scores.dtype), eps_floor)
    # Strictly positive scores for the solver; zero-score clients sit at floor.
    safe = jnp.maximum(scores, 1e-30)
    if shard is None:
        return _isp_solve(safe, budget, p_min_arr)
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu
    return _isp_solve_sharded(
        safe, budget, p_min_arr, shard, use_kernel=use_kernel,
        interpret=not on_tpu,
    )


def rsp_probabilities(scores: jax.Array, budget: float | jax.Array) -> jax.Array:
    """Optimal marginals for the random sampling procedure: K * a / sum(a).

    Clipped to 1 with iterative mass redistribution so the result stays a
    valid marginal vector when K * max(a) > sum(a)  (the paper assumes the
    non-degenerate regime; production code must not produce p > 1).
    """
    scores = jnp.asarray(scores)
    budget = jnp.asarray(budget, dtype=scores.dtype)

    def body(_, p_and_free):
        # redistribute: clients at cap 1 keep it; remaining budget spread
        # proportionally over free clients.
        p, _ = p_and_free
        capped = p >= 1.0
        k_rem = budget - jnp.sum(capped)
        denom = jnp.sum(jnp.where(capped, 0.0, scores))
        p_new = jnp.where(
            capped, 1.0, k_rem * scores / jnp.maximum(denom, 1e-30)
        )
        return p_new, capped

    total = jnp.maximum(jnp.sum(scores), 1e-30)
    p0 = budget * scores / total
    # N iterations suffice in the worst case; a handful in practice.
    p, _ = jax.lax.fori_loop(
        0, 8, body, (p0, jnp.zeros_like(p0, dtype=bool))
    )
    return jnp.clip(p, 0.0, 1.0)


def mix_probabilities(p: jax.Array, theta: float | jax.Array, budget: float | jax.Array) -> jax.Array:
    """Mixing strategy, eq. (12): p~ = (1-theta) p + theta * K/N."""
    p = jnp.asarray(p)
    n = p.shape[0]
    theta = jnp.asarray(theta, p.dtype)
    budget = jnp.asarray(budget, p.dtype)
    return (1.0 - theta) * p + theta * budget / n


def expected_cost(scores: jax.Array, p: jax.Array) -> jax.Array:
    """Online cost l_t(p) = sum_i a_i^2 / p_i (Section 5.1)."""
    scores = jnp.asarray(scores)
    p = jnp.asarray(p)
    return jnp.sum(jnp.where(scores > 0, scores**2 / jnp.maximum(p, 1e-30), 0.0))


def optimal_cost(scores: jax.Array, budget: float | jax.Array) -> jax.Array:
    """min_p l_t(p) over the ISP polytope — used by regret metrics.

    Closed form when no p saturates: (sum a)^2 / K (eq. 39); in general we
    evaluate the cost at the exact solver output.
    """
    p_star = isp_probabilities(scores, budget, p_min=0.0)
    return expected_cost(scores, p_star)
