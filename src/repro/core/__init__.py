"""Core contribution of the paper: adaptive unbiased client sampling.

Public API:
  solver      — budgeted water-filling probabilities (Lemmas 2.2 / 5.1 / B.8)
  samplers    — K-Vib (Algorithm 2) + baselines (uniform, Mabs, Vrb, Avare)
  estimator   — unbiased global estimation d^t and variance diagnostics
  regret      — dynamic/static regret trackers (eqs. 8-9)
"""
from repro.core import estimator, regret, samplers, solver
from repro.core.estimator import (
    aggregate_and_error,
    aggregate_and_error_cohort,
    aggregate_compressed,
)
from repro.core.samplers import (
    Avare,
    assert_serializable_state,
    ClusteredKVib,
    KVib,
    Mabs,
    OptimalISP,
    Osmd,
    SampleResult,
    Sampler,
    SamplerState,
    UniformISP,
    UniformRSP,
    Vrb,
    make_sampler,
    sampler_names,
)
from repro.core.solver import isp_probabilities, mix_probabilities, rsp_probabilities

__all__ = [
    "estimator",
    "regret",
    "samplers",
    "solver",
    "aggregate_and_error",
    "aggregate_and_error_cohort",
    "aggregate_compressed",
    "Avare",
    "ClusteredKVib",
    "KVib",
    "Mabs",
    "OptimalISP",
    "Osmd",
    "SampleResult",
    "Sampler",
    "SamplerState",
    "UniformISP",
    "UniformRSP",
    "Vrb",
    "make_sampler",
    "sampler_names",
    "assert_serializable_state",
    "isp_probabilities",
    "mix_probabilities",
    "rsp_probabilities",
]
