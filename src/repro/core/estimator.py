"""Unbiased global estimation (Definition 2.1) and variance diagnostics.

The server-side estimate of the full-participation update

    d^t = sum_{i in S^t} lambda_i g_i^t / p_i^t          (ISP, mask form)
    d^t = (1/K) sum_{j=1..K} lambda_{i_j} g_{i_j} / q_{i_j}   (RSP-WR form)

operates on *pytrees* of client updates.  Two layouts are supported:

* stacked  — leaves carry a leading client axis (N, ...); used by the
  simulation substrate and the paper-scale experiments.
* weights-only — ``client_weights`` returns the scalar coefficient per client
  so the distributed runtime can pre-scale local shards before the collective
  reduce (DESIGN.md section 3: scale-then-psum, one pass).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.samplers import SampleResult

__all__ = [
    "client_weights",
    "aggregate_stacked",
    "full_aggregate_stacked",
    "aggregate_and_error",
    "aggregate_and_error_cohort",
    "aggregate_compressed",
    "isp_variance",
    "rsp_variance_bound",
    "empirical_sq_error",
]


def client_weights(
    draw: SampleResult, lam: jax.Array, procedure: str, budget: int
) -> jax.Array:
    """Scalar aggregation coefficient per client (zero for unsampled).

    The estimator is always ``d = sum_i w_i g_i`` with w from this function —
    the distributed round pre-scales each client's delta by ``w_i`` locally and
    reduces, so estimation costs one collective regardless of procedure.

    Composed-draw contract: the probabilities used here are ``draw.marginals``
    / ``draw.draw_probs`` verbatim, so a draw whose probabilities were
    composed upstream — e.g. ``core.stragglers.available_draw(draw, avail,
    q)``, which multiplies them by the availability probability ``q`` — makes
    this the corrected estimator (``lam / (q p)``) with no extra bookkeeping.
    The 1e-30 floors below are dead-code guards for the masked-out lanes
    only: a drawn client with a genuinely zero probability is a modeling
    error the composers reject (``stragglers.ZeroAvailabilityError`` on the
    host path, mask-to-zero in-trace) before the weight is formed.
    """
    lam = jnp.asarray(lam)
    if procedure == "isp":
        return jnp.where(
            draw.mask, lam / jnp.maximum(draw.marginals, 1e-30), 0.0
        )
    if procedure == "rsp_wr":
        q = jnp.maximum(draw.draw_probs, 1e-30)
        return draw.counts.astype(lam.dtype) * lam / (budget * q)
    if procedure == "rsp_wor":
        # Uniform without replacement: marginal p_i = K/N exactly.
        return jnp.where(
            draw.mask, lam / jnp.maximum(draw.marginals, 1e-30), 0.0
        )
    raise ValueError(f"unknown procedure {procedure!r}")


def aggregate_stacked(updates, weights: jax.Array):
    """d = sum_i w_i * g_i over a stacked pytree (leading client axis)."""

    def agg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(w * leaf, axis=0)

    return jax.tree_util.tree_map(agg, updates)


def full_aggregate_stacked(updates, lam: jax.Array):
    """Full-participation target sum_i lambda_i g_i."""

    def agg(leaf):
        w = lam.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(w * leaf, axis=0)

    return jax.tree_util.tree_map(agg, updates)


def _flatten_stacked(updates):
    """Stacked pytree (leading client axis N) -> (N, D) f32 + rebuild spec."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    meta = tuple((leaf.shape[1:], leaf.dtype) for leaf in leaves)
    flat = jnp.concatenate(
        [leaf.reshape((leaf.shape[0], -1)).astype(jnp.float32) for leaf in leaves],
        axis=1,
    )
    return flat, (treedef, meta)


def _unflatten_vector(vec: jax.Array, spec):
    treedef, meta = spec
    out, off = [], 0
    for shape, dtype in meta:
        size = math.prod(shape) if shape else 1
        out.append(vec[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def aggregate_and_error(updates, weights: jax.Array, lam: jax.Array):
    """Estimate ``d = sum_i w_i g_i`` AND its squared error against the
    full-participation target ``sum_i lambda_i g_i`` in ONE pass over the
    stacked updates.

    The error vector ``sum_i (w_i - lam_i) g_i`` shares the pass: stacking the
    two weight rows turns both reductions into a single (2, N) x (N, D)
    contraction over the flattened deltas — the largest tensor the server
    touches — routed through ``kernels.fused_weighted_agg`` on TPU.

    Returns (estimate pytree, scalar squared error).
    """
    flat, spec = _flatten_stacked(updates)
    w2 = jnp.stack(
        [weights.astype(jnp.float32), weights.astype(jnp.float32) - lam.astype(jnp.float32)]
    )
    d_dim = flat.shape[1]
    if jax.default_backend() == "tpu" and d_dim % 128 == 0:
        from repro.kernels.fused_weighted_agg import fused_multi_weighted_agg

        out = fused_multi_weighted_agg(flat, w2, block_d=_block_d(d_dim))
    else:
        out = w2 @ flat
    return _unflatten_vector(out[0], spec), jnp.sum(out[1] ** 2)


def _block_d(d_dim: int) -> int:
    return d_dim if d_dim <= 2048 else max(
        b for b in (2048, 1024, 512, 256, 128) if d_dim % b == 0
    )


def aggregate_and_error_cohort(updates, weights: jax.Array, lam_cohort: jax.Array):
    """Cohort-width ``aggregate_and_error``: (C, ...) stacked cohort deltas in,
    no (N, D) materialization anywhere.

    ``updates`` carries a leading *cohort-slot* axis C (not the client axis N);
    ``weights`` is ``sel.weights`` from ``fed.cohort.select_cohort`` (zero on
    padding) and ``lam_cohort`` is lambda gathered at ``sel.ids`` and zeroed on
    padding.  The returned estimate equals the scatter-to-N path's estimate in
    exact arithmetic — the off-cohort rows it sums are identically zero — but
    only to float tolerance on hardware (the reduction runs over C terms
    instead of N, so partial-sum order differs; see fed/cohort.py
    "Aggregation width").  The squared error is the cohort-supported error
    ``|| sum_c (w_c - lam_c) delta_c ||^2``, which is what the scatter path's
    diagnostic row also computes when the off-cohort deltas are zero.

    Returns (estimate pytree, scalar squared error).
    """
    flat, spec = _flatten_stacked(updates)
    d_dim = flat.shape[1]
    if jax.default_backend() == "tpu" and d_dim % 128 == 0:
        from repro.kernels.fused_weighted_agg import fused_cohort_agg_and_error

        d_vec, sq = fused_cohort_agg_and_error(
            flat, weights, lam_cohort, block_d=_block_d(d_dim)
        )
        return _unflatten_vector(d_vec, spec), sq
    w2 = jnp.stack(
        [
            weights.astype(jnp.float32),
            weights.astype(jnp.float32) - lam_cohort.astype(jnp.float32),
        ]
    )
    out = w2 @ flat
    return _unflatten_vector(out[0], spec), jnp.sum(out[1] ** 2)


def aggregate_compressed(
    updates, weights: jax.Array, lam_cohort: jax.Array, compression, resid=None
):
    """Compressed-width ``aggregate_and_error_cohort``: quantize the stacked
    cohort deltas to ``compression.delta_dtype`` with per-(slot, block) fp32
    scales, then aggregate via the fused dequantize-in-VMEM kernel so the
    (C, D) buffer crosses HBM at quantized width exactly once.

    ``resid`` enables server-side error feedback: the applied estimate is
    ``d_hat + resid`` and the returned ``new_resid`` is the fresh
    quantization error ``d_true - d_hat`` (``d_true`` = the uncompressed
    aggregate of the transient f32 deltas — the value a per-client residual
    scheme would reconstruct; errors telescope instead of accumulating).
    With ``resid=None`` the raw ``d_hat`` is applied and ``new_resid`` is
    None — the ablation mode where quantization error random-walks.

    Returns (estimate pytree, err_sq scalar, dequantized norms (C,) f32,
    new_resid (D,) f32 | None).  ``err_sq`` and the norms are computed from
    the dequantized values, so the sampler's regret signal is what the
    estimator actually saw.
    """
    from repro.kernels.fused_weighted_agg import (
        dequant_cohort_agg_reference,
        fused_dequant_cohort_agg,
        quantize_stacked,
    )

    flat, spec = _flatten_stacked(updates)
    d_dim = flat.shape[1]
    q, scales = quantize_stacked(
        flat, dtype=compression.delta_dtype, scale_block=int(compression.scale_block)
    )
    d_pad = q.shape[1]
    sb = d_pad // scales.shape[1]
    if (
        jax.default_backend() == "tpu"
        and d_pad % 128 == 0
        and _block_d(d_pad) % sb == 0
    ):
        d_vec, sq, sqn = fused_dequant_cohort_agg(
            q, scales, weights, lam_cohort, block_d=_block_d(d_pad)
        )
    else:
        d_vec, sq, sqn = dequant_cohort_agg_reference(q, scales, weights, lam_cohort)
    d_hat = d_vec[:d_dim]
    new_resid = None
    if resid is not None:
        d_true = weights.astype(jnp.float32) @ flat
        new_resid = d_true - d_hat
        d_hat = d_hat + resid
    return _unflatten_vector(d_hat, spec), sq, jnp.sqrt(sqn), new_resid


def isp_variance(scores: jax.Array, p: jax.Array) -> jax.Array:
    """Exact ISP estimator variance (Lemma 2.1, equality case):

    V(S) = sum_i (1 - p_i) * a_i^2 / p_i,   a_i = lambda_i ||g_i||.
    """
    scores = jnp.asarray(scores)
    p = jnp.asarray(p)
    return jnp.sum((1.0 - p) * scores**2 / jnp.maximum(p, 1e-30))


def rsp_variance_bound(scores: jax.Array, p: jax.Array, budget: int) -> jax.Array:
    """RSP upper bound of Lemma 2.1: (N-K)/(N-1) * sum_i a_i^2 / p_i."""
    scores = jnp.asarray(scores)
    n = scores.shape[0]
    coef = (n - budget) / max(n - 1, 1)
    return coef * jnp.sum(scores**2 / jnp.maximum(p, 1e-30))


def empirical_sq_error(estimate, target) -> jax.Array:
    """|| d - sum lambda g ||^2 across a pytree."""
    sq = jax.tree_util.tree_map(
        lambda a, b: jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2),
        estimate,
        target,
    )
    return jax.tree_util.tree_reduce(jnp.add, sq)
