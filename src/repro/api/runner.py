"""``run(spec)``: the one facade over both execution stacks.

``build(spec)`` resolves an ``ExperimentSpec``'s registry names into the
concrete objects the stacks consume — task/arch config, federated dataset,
sampler, ``FedConfig``/``RoundSpec`` — and ``run(spec)`` dispatches:

* ``task.kind == "task"`` — the simulation stack:
  ``fed.server.run_federated(task, dataset, sampler, fed_config)``.  The
  spec layer builds the identical objects the legacy call takes, so the two
  entry points are bitwise-equal (tests/test_api_spec.py golden tests).
* ``task.kind == "zoo"`` — the pod-scale compiled stack:
  ``fed.round.build_fed_scan_segment`` on the host mesh, driven by
  ``fed.state.run_segmented`` — the same construction (and key stream) as
  ``repro.launch.train --compiled``.

Both paths accept a ``repro.checkpoint.CheckpointManager`` whose manifest
fingerprint should be ``config_fingerprint(spec.to_dict())`` — the spec IS
the run configuration, so resuming under a changed spec raises.
``restore_template(spec)`` exposes the matching restore template (the fresh
round-0 ``TrainState``) for out-of-band checkpoint surgery.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

import jax
import numpy as np

from repro.api.spec import (
    ExperimentSpec,
    _dataset_registry,
    _task_registry,
    dataset_names,
    task_names,
)
from repro.core.samplers import make_sampler
from repro.fed.server import FedConfig, History, build_segment_runner, run_federated

__all__ = ["BuiltExperiment", "build", "run", "restore_template"]


# Dataset construction is memoized per process: sweeps (budget grids, sampler
# panels) re-reference the identical (factory, kwargs) cell many times, and
# the factories are deterministic pure functions of their kwargs (the
# register_dataset contract), so rebuilding the arrays is pure waste.  The
# cache is tiny — a sweep touches one or two datasets at a time.
_DATASET_CACHE: dict = {}
_DATASET_CACHE_MAX = 4


def _build_dataset(name: str, factory, kwargs: dict):
    key = (name, id(factory), json.dumps(kwargs, sort_keys=True, default=repr))
    if key not in _DATASET_CACHE:
        if len(_DATASET_CACHE) >= _DATASET_CACHE_MAX:
            _DATASET_CACHE.pop(next(iter(_DATASET_CACHE)))
        _DATASET_CACHE[key] = factory(**kwargs)
    return _DATASET_CACHE[key]


@dataclasses.dataclass(frozen=True)
class BuiltExperiment:
    """The resolved pieces of one spec; which fields are set depends on kind.

    kind="task": ``task`` (fed.tasks.Task), ``dataset``, ``sampler``,
    ``fed_config`` — exactly the legacy ``run_federated`` argument tuple.
    kind="zoo": ``arch_config`` (models.common.ArchConfig), ``dataset``,
    ``sampler``, ``round_spec`` — the ``launch.train`` construction set.
    """

    spec: ExperimentSpec
    kind: str
    dataset: Any
    sampler: Any
    task: Any = None  # simulation Task (kind="task")
    fed_config: FedConfig | None = None  # kind="task"
    arch_config: Any = None  # kind="zoo"
    round_spec: Any = None  # kind="zoo"


def _sampler_shard(spec: ExperimentSpec):
    """The ``ShardSpec`` that ``spec.execution.sampler_axis`` denotes (or
    ``None``): the sampler's (N,)-axis layout over the run's mesh — the same
    mesh ``_make_mesh`` hands the zoo stack, so one ``sampler_axis`` switch
    shards the solve/draw/update on both stacks."""
    axis = spec.execution.sampler_axis
    if axis is None:
        return None
    from repro.launch.mesh import ShardSpec

    return ShardSpec.from_mesh(_make_mesh(spec), axis=axis)


def _build_task(spec: ExperimentSpec) -> BuiltExperiment:
    tasks = _task_registry()
    if spec.task.name not in tasks:
        raise ValueError(
            f"unknown task {spec.task.name!r}; registered: {task_names()} "
            "(repro.api.register_task adds custom factories)"
        )
    datasets = _dataset_registry()
    if spec.task.dataset not in datasets:
        raise ValueError(
            f"unknown dataset {spec.task.dataset!r}; registered: {dataset_names()} "
            "(repro.api.register_dataset adds custom factories)"
        )
    task = tasks[spec.task.name](**dict(spec.task.kwargs))
    ds = _build_dataset(
        spec.task.dataset,
        datasets[spec.task.dataset],
        dict(spec.task.dataset_kwargs),
    )
    sampler = make_sampler(
        spec.sampler.name,
        n=ds.n_clients,
        budget=spec.federation.budget,
        shard=_sampler_shard(spec),
        **dict(spec.sampler.kwargs),
    )
    return BuiltExperiment(
        spec=spec,
        kind="task",
        dataset=ds,
        sampler=sampler,
        task=task,
        fed_config=spec.fed_config(),
    )


def _build_zoo(spec: ExperimentSpec) -> BuiltExperiment:
    from repro.configs import get_config, list_archs
    from repro.configs.registry import has_arch

    if not has_arch(spec.task.name):
        raise ValueError(
            f"unknown zoo arch {spec.task.name!r}; options: {list_archs()}"
        )
    cfg = get_config(spec.task.name)
    if spec.task.reduced:
        cfg = cfg.reduced(**dict(spec.task.kwargs))

    datasets = _dataset_registry()
    if spec.task.dataset not in datasets:
        raise ValueError(
            f"unknown dataset {spec.task.dataset!r}; registered: {dataset_names()}"
        )
    ds_kw = dict(spec.task.dataset_kwargs)
    if spec.task.dataset == "synthetic_tokens":
        # The launcher's defaults: vocab from the arch, seed from the run
        # seed, total_seqs sized to the client count.
        ds_kw.setdefault("vocab", cfg.vocab)
        ds_kw.setdefault("seed", spec.execution.seed)
        if "n_clients" in ds_kw:
            ds_kw.setdefault("total_seqs", max(32 * int(ds_kw["n_clients"]), 512))
    ds = _build_dataset(spec.task.dataset, datasets[spec.task.dataset], ds_kw)

    sampler = make_sampler(
        spec.sampler.name,
        n=ds.n_clients,
        budget=spec.federation.budget,
        shard=_sampler_shard(spec),
        **dict(spec.sampler.kwargs),
    )
    fed = spec.federation
    if fed.cohort is None:
        fed = dataclasses.replace(
            fed, cohort=max(1, min(2 * fed.budget, ds.n_clients))
        )
        spec = dataclasses.replace(spec, federation=fed)
    return BuiltExperiment(
        spec=spec,
        kind="zoo",
        dataset=ds,
        sampler=sampler,
        arch_config=cfg,
        round_spec=spec.round_spec(),
    )


def build(spec: ExperimentSpec) -> BuiltExperiment:
    """Resolve a spec's registry names into the concrete experiment objects.

    Pure construction — no training, no device state beyond dataset arrays.
    ``run(spec, built=...)`` accepts the result so drivers that need the
    dataset up front (e.g. to derive eval batches) build exactly once."""
    if spec.task.kind == "zoo":
        return _build_zoo(spec)
    return _build_task(spec)


def _specs_compatible(a: ExperimentSpec, b: ExperimentSpec) -> bool:
    """Equality modulo the one build-time resolution: ``cohort=None`` may
    have been replaced by its concrete default in a built spec."""
    fa, fb = a.federation, b.federation
    if fa.cohort is None or fb.cohort is None:
        fa = dataclasses.replace(fa, cohort=None)
        fb = dataclasses.replace(fb, cohort=None)
    return (a.task, a.sampler, fa, a.execution, a.fault, a.compression, a.serve) == (
        b.task,
        b.sampler,
        fb,
        b.execution,
        b.fault,
        b.compression,
        b.serve,
    )


def _make_mesh(spec: ExperimentSpec):
    from repro.launch.mesh import make_host_mesh

    shape = spec.execution.mesh_shape
    if shape is None:
        return make_host_mesh()
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return jax.make_mesh(shape, axes)


def _zoo_segment_and_state(built: BuiltExperiment):
    """(segment_fn, round-0 TrainState) for the zoo stack — the identical
    construction (and chain-key reuse) as ``repro.launch.train --compiled``."""
    from repro.fed.round import build_fed_scan_segment
    from repro.models import transformer

    spec = built.spec
    key = jax.random.PRNGKey(spec.execution.seed)
    params = transformer.init_params(built.arch_config, key)
    segment, make_state = build_fed_scan_segment(
        built.arch_config,
        built.round_spec,
        built.sampler,
        built.dataset,
        mesh=_make_mesh(spec),
    )
    state = make_state(params, built.sampler.init(), key, spec.federation.rounds)
    return segment, state


def _run_zoo(built: BuiltExperiment, ckpt_manager, publish=None) -> History:
    from repro.fed.state import run_segmented

    spec = built.spec
    t0 = time.time()
    ckpt_every = spec.execution.ckpt_every
    if ckpt_manager is not None and ckpt_every <= 0:
        raise ValueError(
            "run(spec, ckpt_manager=...) needs execution.ckpt_every > 0; "
            f"got ckpt_every={ckpt_every}"
        )
    segment, state = _zoo_segment_and_state(built)
    if ckpt_manager is not None:
        state, _ = ckpt_manager.restore_or_init(state)
    state = run_segmented(
        state,
        spec.federation.rounds,
        segment,
        ckpt_every=ckpt_every,
        manager=ckpt_manager,
        publish=publish,
    )
    jax.block_until_ready(state)

    params = state.params
    fault = spec.fault
    if fault.enabled and int(fault.async_buffer) > 0:
        # End-of-horizon flush of still-pending stale deltas (mid-run segment
        # boundaries keep the buffer in the carry — core.stragglers).
        from repro.core import stragglers

        buf = state.faults["buf"]
        if np.asarray(buf["valid"]).any():
            pending = stragglers.flush_pending(
                buf, spec.federation.rounds, float(fault.staleness_discount)
            )
            d_pend = stragglers.vec_to_tree(pending, params)
            params = jax.tree_util.tree_map(lambda p, g: p - g, params, d_pend)

    hist = History()
    hist.rounds = list(range(spec.federation.rounds))
    hist.train_loss = [float(x) for x in np.asarray(state.metrics["loss"])]
    hist.cohort_size = [int(x) for x in np.asarray(state.metrics["cohort_size"])]
    hist.cohort_dropped = [int(x) for x in np.asarray(state.metrics["dropped"])]
    if "deadline_dropped" in state.metrics:
        hist.deadline_dropped = [
            int(x) for x in np.asarray(state.metrics["deadline_dropped"])
        ]
    hist.final_params = jax.tree_util.tree_map(np.asarray, params)
    hist.wall_time_s = time.time() - t0
    return hist


def run(
    spec: ExperimentSpec,
    *,
    eval_data: tuple | None = None,
    ckpt_manager=None,
    built: BuiltExperiment | None = None,
    publish=None,
) -> History:
    """Execute a spec end to end; the one front door for both stacks.

    ``eval_data`` — optional (x, y) evaluation batch for the simulation
    stack's accuracy curve (``FederationSpec.eval_every`` schedule).
    ``ckpt_manager`` — a ``repro.checkpoint.CheckpointManager``: restore-or-
    init before running, publish the full ``TrainState`` at every
    ``execution.ckpt_every`` segment boundary.  Its fingerprint should be
    ``config_fingerprint(spec.to_dict())``.
    ``built`` — a prior ``build(spec)`` result to reuse (must be from an
    equal spec).
    ``publish`` — ``(state, rounds_done)`` callback fired after each
    boundary's manifest commit (zoo stack; needs ``ckpt_manager``): the
    train side of the ``repro.serve`` hand-off."""
    if built is None:
        built = build(spec)
    elif not _specs_compatible(built.spec, spec):
        raise ValueError("run(built=...) got a BuiltExperiment from a different spec")
    if ckpt_manager is not None and getattr(ckpt_manager, "layout", None) is None:
        # Record the run's sampler (N,)-axis layout in the manifest
        # (provenance only — restore never validates it).
        ckpt_manager.layout = built.sampler.shard
    if built.kind == "zoo":
        if eval_data is not None:
            raise ValueError(
                "eval_data is only supported on the simulation stack "
                "(kind='task'); the zoo stack's metrics are train loss / "
                "cohort size / drops"
            )
        return _run_zoo(built, ckpt_manager, publish)
    if publish is not None:
        raise ValueError(
            "run(spec, publish=...) is a zoo-stack feature (kind='zoo'): "
            "the serve hand-off follows the segmented TrainState manager"
        )
    return run_federated(
        built.task,
        built.dataset,
        built.sampler,
        built.fed_config,
        eval_data=eval_data,
        ckpt_manager=ckpt_manager,
    )


def restore_template(
    spec: ExperimentSpec, *, built: BuiltExperiment | None = None
):
    """The fresh round-0 ``TrainState`` a checkpoint of this spec restores
    into (``CheckpointManager.restore(template)``) — for either stack.

    ``run(spec, ckpt_manager=...)`` constructs this internally; it is exposed
    for out-of-band checkpoint inspection/surgery."""
    if built is None:
        built = build(spec)
    if built.kind == "zoo":
        _, state = _zoo_segment_and_state(built)
        return state
    cfg = built.fed_config
    if not cfg.compiled:
        raise ValueError(
            "restore templates exist only for the compiled execution path "
            "(execution.compiled=False has no checkpointable TrainState)"
        )
    _, state = build_segment_runner(
        built.task, built.dataset, built.sampler, cfg, None
    )
    return state
