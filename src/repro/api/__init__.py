"""Declarative experiment API: one ``ExperimentSpec``, one ``run``.

The canonical front door for every scenario this repo can execute::

    from repro.api import ExperimentSpec, TaskSpec, SamplerSpec, run

    spec = ExperimentSpec(
        task=TaskSpec(name="logreg", dataset="synthetic_classification",
                      dataset_kwargs={"n_clients": 100, "total": 20000}),
        sampler=SamplerSpec(name="kvib", kwargs={"horizon": 200}),
    )
    history = run(spec)

    spec.save("experiment.json")          # lossless JSON round trip
    spec2 = ExperimentSpec.load("experiment.json")
    assert spec2 == spec

The same spec drives the CLI (``python -m repro.launch.train --spec
experiment.json`` / ``--dump-spec``), the checkpoint manifest fingerprint
(``repro.checkpoint.config_fingerprint(spec.to_dict())``), the examples, and
the benchmarks — "new scenario = new spec JSON".
"""
from repro.api.runner import BuiltExperiment, build, restore_template, run


def lint(spec, **kwargs):
    """Statically lint a spec's traced program — width / scan-safety /
    dtype / compile-once contracts — without training it.  Thin forwarder to
    ``repro.analysis.lint.run_suite`` (imported lazily: the analysis package
    is optional at run time); returns its ``LintReport``."""
    from repro.analysis.lint import run_suite

    return run_suite(spec, **kwargs)
from repro.api.spec import (
    CompressionSpec,
    ExecutionSpec,
    ExperimentSpec,
    FaultSpec,
    FederationSpec,
    SamplerSpec,
    ServeSpec,
    TaskSpec,
    dataset_names,
    register_dataset,
    register_task,
    server_opt_names,
    task_names,
)

__all__ = [
    "ExperimentSpec",
    "TaskSpec",
    "SamplerSpec",
    "FederationSpec",
    "ExecutionSpec",
    "FaultSpec",
    "CompressionSpec",
    "ServeSpec",
    "BuiltExperiment",
    "build",
    "run",
    "lint",
    "restore_template",
    "register_task",
    "register_dataset",
    "task_names",
    "dataset_names",
    "server_opt_names",
]
