"""The declarative experiment description: ``ExperimentSpec``.

One frozen, nested pytree-of-dataclasses describes a complete experiment —
*what* to train (``TaskSpec``), *how* to sample clients (``SamplerSpec``),
the federated-optimization hyperparameters (``FederationSpec``), and the
execution strategy (``ExecutionSpec``).  The spec is the single source of
truth consumed by every front door in the repo:

* ``repro.api.run(spec)`` dispatches to the simulation stack
  (``fed.server.run_federated``) or the pod-scale compiled stack
  (``fed.round.build_fed_scan_segment`` + ``fed.state.run_segmented``);
* ``repro.launch.train`` parses its CLI flags *into* a spec (``--dump-spec``
  prints it, ``--spec file.json`` loads one directly);
* ``repro.checkpoint.config_fingerprint(spec.to_dict())`` is the manifest
  compatibility guard — ANY field change yields a different fingerprint;
* the examples and ``benchmarks/run.py`` construct specs instead of raw
  ``FedConfig`` / ``RoundSpec`` tuples.

Serialization contract
----------------------

``to_dict()`` / ``from_dict()`` are lossless and JSON-stable:

* ``spec -> to_dict() -> json -> from_dict()`` is the identity (tuples are
  normalized at construction so the JSON list round trip cannot introduce
  drift);
* unknown keys are REJECTED with an error naming the bad field and its
  section — a typo'd hyperparameter can never be silently ignored;
* free-form ``kwargs`` mappings (task factory, sampler, server optimizer)
  pass through verbatim, so registry-resolved components stay extensible
  without schema churn.

The spec layer *builds* the same objects the legacy entry points take —
``api.run(spec)`` reproduces ``run_federated(task, dataset, sampler, cfg)``
bitwise (tests/test_api_spec.py golden tests).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from repro.fed.server import FedConfig
from repro.optim.fedopt import FedAdam, FedAvgServer, ServerOptimizer

__all__ = [
    "TaskSpec",
    "SamplerSpec",
    "FederationSpec",
    "ExecutionSpec",
    "FaultSpec",
    "CompressionSpec",
    "ServeSpec",
    "ExperimentSpec",
    "register_task",
    "register_dataset",
    "task_names",
    "dataset_names",
    "server_opt_names",
]


# ---------------------------------------------------------------------------
# Component registries: name -> factory.  The built-in entries cover the
# paper experiments; ``register_task`` / ``register_dataset`` let drivers add
# scenario-specific factories (examples/femnist_style.py registers its
# vision-like generator, examples/fed_lm.py its zoo-backed LM task) while
# keeping the spec itself a plain name + kwargs record.
# ---------------------------------------------------------------------------


def _builtin_tasks() -> dict:
    from repro.fed import tasks

    return {
        "logreg": tasks.logistic_regression,
        "mlp": tasks.mlp_classifier,
        "tiny_lm": tasks.tiny_lm,
    }


def _builtin_datasets() -> dict:
    from repro.data import synthetic_classification, synthetic_tokens

    return {
        "synthetic_classification": synthetic_classification,
        "synthetic_tokens": synthetic_tokens,
    }


_TASKS: dict = {}
_DATASETS: dict = {}
_SERVER_OPTS: dict[str, type[ServerOptimizer]] = {
    "fedavg": FedAvgServer,
    "fedadam": FedAdam,
}


def _task_registry() -> dict:
    if not _TASKS:
        _TASKS.update(_builtin_tasks())
    return _TASKS


def _dataset_registry() -> dict:
    if not _DATASETS:
        _DATASETS.update(_builtin_datasets())
    return _DATASETS


def register_task(name: str, factory) -> None:
    """Register a ``Task`` factory under ``name`` for ``TaskSpec.name``.

    The factory is called with ``TaskSpec.kwargs``.  Registration is additive
    process state: a spec referencing a custom name deserializes fine but can
    only be *built* in a process that registered the factory."""
    _task_registry()[str(name)] = factory


def register_dataset(name: str, factory) -> None:
    """Register a dataset factory under ``name`` for ``TaskSpec.dataset``.

    Factories must be deterministic pure functions of their kwargs (seed
    included in the kwargs): the build layer memoizes construction per
    process, so sweeps that re-reference the same (dataset, kwargs) cell —
    a budget grid, a sampler panel — share one materialized dataset."""
    _dataset_registry()[str(name)] = factory


def task_names() -> list[str]:
    return sorted(_task_registry())


def dataset_names() -> list[str]:
    return sorted(_dataset_registry())


def server_opt_names() -> list[str]:
    return sorted(_SERVER_OPTS)


# ---------------------------------------------------------------------------
# Normalization helpers: JSON has no tuples, so every sequence inside a spec
# is normalized to a tuple (and every mapping to a plain dict) at
# construction time — ``from_dict(json.loads(to_json()))`` is then the
# identity, not merely an approximation.
# ---------------------------------------------------------------------------


def _normalize(value):
    if isinstance(value, Mapping):
        return {str(k): _normalize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return tuple(_normalize(v) for v in value)
    return value


def _jsonable(value):
    """The inverse direction: tuples -> lists for JSON emission."""
    if isinstance(value, Mapping):
        return {k: _jsonable(v) for k, v in value.items()}
    if isinstance(value, tuple):
        return [_jsonable(v) for v in value]
    return value


def _from_section(cls, section: str, data: Any):
    """Instantiate a spec dataclass from a dict, rejecting unknown keys with
    an error that names the bad field and where it was found."""
    if not isinstance(data, Mapping):
        raise ValueError(
            f"spec section {section!r} must be a mapping, got {type(data).__name__}"
        )
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - fields)
    if unknown:
        raise ValueError(
            f"unknown field {unknown[0]!r} in spec section {section!r} "
            f"(valid fields: {sorted(fields)})"
        )
    return cls(**dict(data))


# ---------------------------------------------------------------------------
# The spec dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """What to train and on which federated data.

    kind:
        ``"task"`` — a simulation-scale ``repro.fed.tasks.Task`` resolved
        from the task registry (``name`` + ``kwargs``); runs through
        ``fed.server.run_federated``.
        ``"zoo"`` — an architecture from ``repro.configs`` (``name`` is the
        registry arch name, ``reduced``/``kwargs`` configure
        ``ArchConfig.reduced(**kwargs)``); runs through the pod-scale
        compiled stack (``fed.round.build_fed_scan_segment``).
    dataset / dataset_kwargs:
        Dataset factory name (dataset registry) and its kwargs.  For zoo
        archs, ``vocab``, ``seed``, and ``total_seqs`` default from the arch
        config and execution seed at build time when omitted.
    """

    kind: str = "task"  # "task" | "zoo"
    name: str = "logreg"
    kwargs: dict = dataclasses.field(default_factory=dict)
    reduced: bool = False  # zoo only: start from ArchConfig.reduced()
    dataset: str = "synthetic_classification"
    dataset_kwargs: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in ("task", "zoo"):
            raise ValueError(
                f"TaskSpec.kind must be 'task' or 'zoo', got {self.kind!r}"
            )
        if self.kind == "task" and self.reduced:
            raise ValueError(
                "TaskSpec.reduced applies only to kind='zoo' (it selects "
                "ArchConfig.reduced()); it has no effect on a simulation task "
                "and would only perturb the config fingerprint"
            )
        if self.kind == "zoo" and self.kwargs and not self.reduced:
            raise ValueError(
                "TaskSpec.kwargs for kind='zoo' are ArchConfig.reduced() "
                "overrides and require reduced=True; a full-size arch takes "
                "no construction kwargs"
            )
        object.__setattr__(self, "kwargs", _normalize(self.kwargs))
        object.__setattr__(self, "dataset_kwargs", _normalize(self.dataset_kwargs))


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Client sampler: a ``repro.core.make_sampler`` registry name + kwargs.

    ``n`` and ``budget`` are NOT spec fields — they derive from the built
    dataset and ``FederationSpec.budget`` so the three sections cannot
    disagree about the client population."""

    name: str = "kvib"
    kwargs: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "kwargs", _normalize(self.kwargs))


@dataclasses.dataclass(frozen=True)
class FederationSpec:
    """Algorithm 1's federated-optimization hyperparameters.

    ``batch_size`` is the per-client local batch (``FedConfig.batch_size`` on
    the simulation stack, ``RoundSpec.local_batch`` on the pod-scale stack);
    ``cohort=None`` means the deployable cohort buffer defaults to
    ``min(2 * budget, n_clients)`` on either stack."""

    rounds: int = 100
    budget: int = 10
    cohort: int | None = None
    local_steps: int = 1
    batch_size: int = 64
    local_lr: float = 0.02
    server_opt: str = "fedavg"
    server_opt_kwargs: dict = dataclasses.field(default_factory=dict)
    eval_every: int = 5
    eval_batches: int = 4

    def __post_init__(self):
        if self.server_opt not in _SERVER_OPTS:
            raise ValueError(
                f"unknown server_opt {self.server_opt!r}; "
                f"options: {server_opt_names()}"
            )
        object.__setattr__(
            self, "server_opt_kwargs", _normalize(self.server_opt_kwargs)
        )

    def build_server_opt(self) -> ServerOptimizer:
        return _SERVER_OPTS[self.server_opt](**dict(self.server_opt_kwargs))


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """How (not what) to execute: seeds, compilation, fidelity, checkpoints.

    ``mesh_shape`` (zoo stack only): explicit host-mesh shape, e.g.
    ``(2, 1)`` for 2-way data parallelism; ``None`` uses
    ``repro.launch.mesh.make_host_mesh()``'s device-derived default.

    ``sampler_axis``: name of the mesh axis to shard every sampler (N,)-axis
    tensor over — the million-client switch.  ``None`` (default) keeps the
    sampler replicated; setting it makes ``repro.api.build`` hand the
    sampler a ``repro.launch.mesh.ShardSpec`` so the budget solve, the
    draw, and the feedback update all run shard-local on BOTH execution
    stacks (see ``core/solver.py``'s sharded-solve contract).

    ``score_history_host_offload``: shrink the oracle (T, N) score-history
    buffer to a per-segment device ring drained to host every ``ckpt_every``
    rounds (simulation stack; requires ``ckpt_every > 0``)."""

    seed: int = 0
    compiled: bool = True
    oracle_metrics: bool = True
    exact_oracle_equiv: bool = False
    track_scores: bool = True
    ckpt_every: int = 0
    mesh_shape: tuple | None = None
    sampler_axis: str | None = None
    score_history_host_offload: bool = False

    def __post_init__(self):
        if self.mesh_shape is not None:
            object.__setattr__(
                self, "mesh_shape", tuple(int(x) for x in self.mesh_shape)
            )


_AVAILABILITY_MODES = (None, "bernoulli", "markov", "diurnal")
_LATENCY_DISTS = ("exponential", "uniform", "lognormal")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Deployment-realism axes: availability, deadline stragglers, async.

    The default-constructed spec is fully OFF (``enabled`` is False) and
    both stacks then run the exact PR-7 round body — the fault layer is a
    build-time branch, not a runtime mask, so disabling it reproduces
    pre-fault results bitwise.  All three axes are independent and compose:

    availability / availability_kwargs:
        Time-varying client availability process intersected with every
        sampler's draw (``core.stragglers.availability_step``):
        ``"bernoulli"`` (``q``: scalar or per-client tuple in [0, 1]),
        ``"markov"`` (per-client on/off chain; ``p_on`` = P(off->on),
        ``p_off`` = P(on->off); the chain state lives in the ``TrainState``
        carry), ``"diurnal"`` (deterministic schedule; ``period``, ``duty``).
        The estimator stays unbiased via the composed ``q * p`` correction
        (``core.stragglers.available_draw``).
    deadline / latency / latency_kwargs:
        ``deadline`` (a positive float, ``None`` = off) drops clients whose
        in-trace latency draw exceeds it AFTER local training is scheduled;
        survivor weights are rescaled by ``1 / P(latency <= deadline)``.
        ``latency`` picks the distribution: ``"exponential"`` (``scale``),
        ``"uniform"`` (``lo``, ``hi``), ``"lognormal"`` (``mu``, ``sigma``).
    async_buffer / staleness_discount / round_time:
        ``async_buffer = B > 0`` switches the server to buffered-async
        aggregation: each round's aggregate enters a carried (B, D) ring
        buffer with an in-trace latency-derived arrival round (latency
        quantized by ``round_time``, which defaults to ``deadline`` then
        1.0) and is applied ``staleness_discount ** staleness``-weighted
        when it arrives; still-pending deltas flush once after the horizon.
    """

    availability: str | None = None
    availability_kwargs: dict = dataclasses.field(default_factory=dict)
    deadline: float | None = None
    latency: str = "exponential"
    latency_kwargs: dict = dataclasses.field(default_factory=dict)
    async_buffer: int = 0
    staleness_discount: float = 0.5
    round_time: float | None = None

    def __post_init__(self):
        object.__setattr__(
            self, "availability_kwargs", _normalize(self.availability_kwargs)
        )
        object.__setattr__(self, "latency_kwargs", _normalize(self.latency_kwargs))
        if self.availability not in _AVAILABILITY_MODES:
            raise ValueError(
                f"unknown availability process {self.availability!r}; "
                f"options: {[m for m in _AVAILABILITY_MODES if m]} or null"
            )
        kw = dict(self.availability_kwargs)
        if self.availability is None and kw:
            raise ValueError(
                "FaultSpec.availability_kwargs given but availability is null"
            )
        if self.availability == "bernoulli":
            q = kw.get("q", 0.9)
            qs = [float(v) for v in (q if isinstance(q, tuple) else (q,))]
            if any(not (0.0 <= v <= 1.0) for v in qs):
                raise ValueError(f"bernoulli availability q must lie in [0, 1], got {q!r}")
            if all(v == 0.0 for v in qs):
                raise ValueError("bernoulli availability q is all-zero: no client is ever available")
        elif self.availability == "markov":
            p_on = float(kw.get("p_on", 0.5))
            p_off = float(kw.get("p_off", 0.5))
            if not (0.0 < p_on <= 1.0):
                raise ValueError(f"markov p_on must lie in (0, 1], got {p_on}")
            if not (0.0 <= p_off < 1.0):
                raise ValueError(f"markov p_off must lie in [0, 1), got {p_off}")
        elif self.availability == "diurnal":
            period = float(kw.get("period", 24.0))
            duty = float(kw.get("duty", 0.5))
            if period <= 0.0:
                raise ValueError(f"diurnal period must be positive, got {period}")
            if not (0.0 < duty <= 1.0):
                raise ValueError(f"diurnal duty must lie in (0, 1], got {duty}")
        if self.latency not in _LATENCY_DISTS:
            raise ValueError(
                f"unknown latency distribution {self.latency!r}; "
                f"options: {list(_LATENCY_DISTS)}"
            )
        if self.deadline is not None:
            if float(self.deadline) <= 0.0:
                raise ValueError(f"deadline must be positive, got {self.deadline}")
            # Raises when P(latency <= deadline) ~ 0 (no unbiased reweighting
            # exists); also validates the latency kwargs for the chosen dist.
            from repro.core.stragglers import deadline_survival

            deadline_survival(self)
        if int(self.async_buffer) < 0:
            raise ValueError(f"async_buffer must be >= 0, got {self.async_buffer}")
        if not (0.0 < float(self.staleness_discount) <= 1.0):
            raise ValueError(
                f"staleness_discount must lie in (0, 1], got {self.staleness_discount}"
            )
        if self.round_time is not None and float(self.round_time) <= 0.0:
            raise ValueError(f"round_time must be positive, got {self.round_time}")

    @property
    def enabled(self) -> bool:
        """True when ANY fault axis is on (the build-time branch switch)."""
        return (
            self.availability is not None
            or self.deadline is not None
            or int(self.async_buffer) > 0
        )


_DELTA_DTYPES = (None, "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Delta-width axis: quantized client deltas with server error feedback.

    The default-constructed spec is fully OFF (``enabled`` is False) and both
    stacks then run the exact pre-compression round body — like ``FaultSpec``
    this is a build-time branch, not a runtime mask, so a disabled spec
    reproduces uncompressed results bitwise through segmentation and resume.

    delta_dtype:
        ``"int8"`` (symmetric round-to-nearest, +-127) or ``"fp8"``
        (float8_e4m3fn, where the installed jax supports it); ``None`` = off.
        Client deltas are quantized inside the traced round body with one
        fp32 abs-max scale per (cohort slot, ``scale_block``-wide block), so
        the (C, D) stacked buffer lives in HBM at quantized width and is
        widened to f32 only inside the fused aggregation kernel's VMEM tiles
        (``kernels.fused_dequant_cohort_agg``).  Sampler feedback norms are
        computed from the dequantized values — the regret signal is what the
        estimator actually saw.
    error_feedback:
        When True (default) the server carries a (D,) f32 residual in
        ``TrainState``: each round applies ``d_hat + resid`` and stores the
        fresh quantization error ``d_true - d_hat``, so errors telescope
        instead of accumulating and the final loss stays allclose to the
        uncompressed run.  The residual rides the carry, so SIGKILL/resume
        and sampler-axis sharding stay exact under compression.
    scale_block:
        Block width (in flattened-param elements) sharing one fp32 scale.
        Default 128 — one scale per TPU lane tile; D is zero-padded
        internally to a block multiple.
    """

    delta_dtype: str | None = None
    error_feedback: bool = True
    scale_block: int = 128

    def __post_init__(self):
        if self.delta_dtype not in _DELTA_DTYPES:
            raise ValueError(
                f"unknown delta_dtype {self.delta_dtype!r}; "
                f"options: {[d for d in _DELTA_DTYPES if d]} or null"
            )
        if self.delta_dtype == "fp8":
            import jax.numpy as jnp

            if not hasattr(jnp, "float8_e4m3fn"):
                raise ValueError(
                    "delta_dtype 'fp8' needs jnp.float8_e4m3fn (jax too old)"
                )
        if int(self.scale_block) <= 0:
            raise ValueError(
                f"scale_block must be positive, got {self.scale_block}"
            )

    @property
    def enabled(self) -> bool:
        """True when a quantized delta width is selected."""
        return self.delta_dtype is not None


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """The serving-side geometry and policy (``repro.serve``).

    Like every spec section this is part of the run's identity: the config
    fingerprint covers it, so a server following a checkpoint directory
    (``launch.serve --follow``) provably agrees with the trainer about how
    the model is served, not just how it was trained.  Old spec JSONs
    without a ``serve`` section deserialize to these defaults.

    batch / prompt_len / max_tokens:
        Lockstep decode geometry: ``batch`` concurrent sequences, each
        prefilled from a ``prompt_len``-token prompt and decoded for up to
        ``max_tokens`` new tokens before the batch is refilled (the paged
        cache is allocated for ``prompt_len + max_tokens`` positions).
    page_size:
        KV-cache page width (``models.attention.init_paged_kv_cache``).
    temperature:
        Sampling temperature; 0 = greedy.  Traced data in the decode step —
        changing it never recompiles.
    decode_steps_per_poll:
        Decode chunk length between manifest polls in the serving loop —
        the swap-latency vs. throughput knob.
    eval_batches / tolerance:
        Promotion gate: number of fixed held-out batches scored per
        candidate boundary (batch size follows
        ``FederationSpec.batch_size``, mirroring the simulation stack's
        ``eval_batches`` convention) and the promote slack
        (``loss <= best + tolerance``).
    """

    batch: int = 2
    prompt_len: int = 16
    max_tokens: int = 48
    page_size: int = 16
    temperature: float = 0.0
    decode_steps_per_poll: int = 16
    eval_batches: int = 4
    tolerance: float = 0.0

    def __post_init__(self):
        for field in ("batch", "prompt_len", "max_tokens", "page_size",
                      "decode_steps_per_poll", "eval_batches"):
            if int(getattr(self, field)) < 1:
                raise ValueError(
                    f"ServeSpec.{field} must be >= 1, got {getattr(self, field)}"
                )
        if float(self.temperature) < 0.0:
            raise ValueError(
                f"ServeSpec.temperature must be >= 0, got {self.temperature}"
            )
        if float(self.tolerance) < 0.0:
            raise ValueError(
                f"ServeSpec.tolerance must be >= 0, got {self.tolerance}"
            )

    @property
    def max_seq(self) -> int:
        """The paged cache's static capacity per sequence."""
        return int(self.prompt_len) + int(self.max_tokens)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The canonical, serializable description of one experiment.

    ``repro.api.run(spec)`` executes it; ``to_dict()``'s canonical form is
    what checkpoint manifests fingerprint and what ``--dump-spec`` emits."""

    task: TaskSpec = dataclasses.field(default_factory=TaskSpec)
    sampler: SamplerSpec = dataclasses.field(default_factory=SamplerSpec)
    federation: FederationSpec = dataclasses.field(default_factory=FederationSpec)
    execution: ExecutionSpec = dataclasses.field(default_factory=ExecutionSpec)
    fault: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    compression: CompressionSpec = dataclasses.field(default_factory=CompressionSpec)
    serve: ServeSpec = dataclasses.field(default_factory=ServeSpec)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        """Lossless plain-dict form (JSON-ready: tuples become lists)."""
        return _jsonable(
            {
                "task": dataclasses.asdict(self.task),
                "sampler": dataclasses.asdict(self.sampler),
                "federation": dataclasses.asdict(self.federation),
                "execution": dataclasses.asdict(self.execution),
                "fault": dataclasses.asdict(self.fault),
                "compression": dataclasses.asdict(self.compression),
                "serve": dataclasses.asdict(self.serve),
            }
        )

    @classmethod
    def from_dict(cls, data: Mapping) -> "ExperimentSpec":
        """Inverse of ``to_dict``; unknown keys raise, naming the field."""
        if not isinstance(data, Mapping):
            raise ValueError(
                f"ExperimentSpec.from_dict needs a mapping, got {type(data).__name__}"
            )
        sections = {
            "task": TaskSpec,
            "sampler": SamplerSpec,
            "federation": FederationSpec,
            "execution": ExecutionSpec,
            "fault": FaultSpec,
            "compression": CompressionSpec,
            "serve": ServeSpec,
        }
        unknown = sorted(set(data) - set(sections))
        if unknown:
            raise ValueError(
                f"unknown field {unknown[0]!r} in ExperimentSpec "
                f"(valid sections: {sorted(sections)})"
            )
        built = {
            key: _from_section(sec_cls, key, data[key])
            for key, sec_cls in sections.items()
            if key in data
        }
        return cls(**built)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- legacy-config projections ------------------------------------------
    def fed_config(self) -> FedConfig:
        """The simulation stack's ``FedConfig`` this spec denotes — the exact
        object the legacy ``run_federated(task, dataset, sampler, cfg)`` call
        would have taken (golden bit-identity depends on this mapping)."""
        fed, ex = self.federation, self.execution
        return FedConfig(
            rounds=fed.rounds,
            budget=fed.budget,
            local_steps=fed.local_steps,
            batch_size=fed.batch_size,
            local_lr=fed.local_lr,
            server_opt=fed.build_server_opt(),
            seed=ex.seed,
            eval_every=fed.eval_every,
            eval_batches=fed.eval_batches,
            oracle_metrics=ex.oracle_metrics,
            compiled=ex.compiled,
            cohort=fed.cohort,
            exact_oracle_equiv=ex.exact_oracle_equiv,
            track_scores=ex.track_scores,
            ckpt_every=ex.ckpt_every,
            score_history_host_offload=ex.score_history_host_offload,
            faults=self.fault if self.fault.enabled else None,
            compression=self.compression if self.compression.enabled else None,
        )

    def round_spec(self):
        """The pod-scale stack's ``RoundSpec`` this spec denotes (zoo kind).

        ``cohort=None`` resolves at build time (``repro.api.build``) where
        the client count is known; here it must already be concrete."""
        from repro.fed.round import RoundSpec

        fed = self.federation
        if fed.cohort is None:
            raise ValueError(
                "FederationSpec.cohort is None; resolve it against the client "
                "count first (repro.api.build does this automatically)"
            )
        if fed.server_opt != "fedavg":
            raise ValueError(
                f"server_opt {fed.server_opt!r} is only supported on the "
                "simulation stack (kind='task'); the pod-scale round applies "
                "a stateless x - server_lr * d update (fedavg)"
            )
        server_lr = float(dict(fed.server_opt_kwargs).get("lr", 1.0))
        return RoundSpec(
            cohort=int(fed.cohort),
            local_steps=fed.local_steps,
            local_lr=fed.local_lr,
            server_lr=server_lr,
            local_batch=fed.batch_size,
            faults=self.fault if self.fault.enabled else None,
            compression=self.compression if self.compression.enabled else None,
        )
