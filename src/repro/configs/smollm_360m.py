"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152,
llama-architecture small model. [hf:HuggingFaceTB/SmolLM-135M family]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    block_pattern=("attn",),
    rope_theta=1e4,
    tie_embeddings=True,
    round_mode="client_parallel",
    long_context_ok=False,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
