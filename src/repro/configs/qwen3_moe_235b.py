"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B scaled per
assignment]  Qwen3 uses explicit head_dim=128 with per-head q/k RMSNorm."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    block_pattern=("moe",),
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    tie_embeddings=False,
    round_mode="cohort_sequential",
    long_context_ok=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
