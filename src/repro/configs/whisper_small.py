"""whisper-small [audio] — enc-dec, 12L encoder + 12L decoder, d_model=768,
12H (kv=12), d_ff=3072, vocab=51865.  [arXiv:2212.04356]
The mel-spectrogram + conv frontend is STUBBED: input_specs provides
precomputed frame embeddings (1500 frames post-conv) per the assignment
carve-out; the encoder transformer consumes them."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder depth
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    block_pattern=("dec",),
    encoder_layers=12,
    frontend="audio",
    frontend_seq=1500,
    frontend_dim=768,
    act="gelu",
    tie_embeddings=True,
    round_mode="client_parallel",
    long_context_ok=False,  # full attention enc-dec
    source="arXiv:2212.04356",
)
