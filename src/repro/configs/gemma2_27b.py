"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; alternating local(4096-window)/global attention, attention and
final logit soft-capping, embedding scaling. [arXiv:2408.00118]
long_500k is SKIPPED: the global layers are quadratic (DESIGN.md section 4)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    scale_embed=True,
    act="gelu",
    tie_embeddings=True,
    round_mode="cohort_sequential",
    long_context_ok=False,
    source="arXiv:2408.00118",
)
