"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (blocks carry their own projections; d_ff=0 per spec).
[arXiv:2405.04517]  Recurrent O(1) state -> runs long_500k decode."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    round_mode="client_parallel",
    long_context_ok=True,
    source="arXiv:2405.04517",
)
