"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B]
long_500k runs via the beyond-paper sliding-window variant (window 8192):
the paper-assigned dense arch is quadratic, but the framework exposes a
block-local attention switch, exercised by this config's `sw` sibling."""
import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    rope_theta=5e5,
    block_pattern=("attn",),
    tie_embeddings=True,
    round_mode="client_parallel",
    long_context_ok=True,  # served long-context via the sliding-window variant
    sliding_window=8192,  # used only by "attn_local" blocks — see SW_CONFIG
    source="hf:meta-llama/Llama-3.2-1B",
)

# Beyond-paper long-context variant: all layers sliding-window (8192).
SW_CONFIG = dataclasses.replace(
    CONFIG, name="llama3.2-1b-sw", block_pattern=("attn_local",)
)
