"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS an always-on dense residual MLP branch.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,  # dense residual branch width
    vocab=32000,
    block_pattern=("moe",),
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    tie_embeddings=False,
    round_mode="cohort_sequential",
    long_context_ok=False,
    source="hf:Snowflake/snowflake-arctic-base",
)
