from repro.configs.registry import (
    ARCH_MODULES,
    INPUT_SHAPES,
    get_config,
    input_specs,
    list_archs,
    step_kind,
)

__all__ = [
    "ARCH_MODULES",
    "INPUT_SHAPES",
    "get_config",
    "input_specs",
    "list_archs",
    "step_kind",
]
