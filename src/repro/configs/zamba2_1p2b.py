"""zamba2-1.2b [hybrid] — 38 blocks d_model=2048, Mamba2 backbone
(ssm_state=64) + a SHARED attention block (32H kv=32, d_ff=8192) invoked at
fixed positions with shared weights. [arXiv:2411.15242]
Pattern: 19-slot group (18 mamba2 + 1 shared_attn) x 2 = 38 blocks; the
shared block's weights are stored once (params['shared']) while its KV cache
is per-invocation.  Mamba2 state is O(1) -> runs long_500k decode."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    block_pattern=("mamba2",) * 18 + ("shared_attn",),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    round_mode="client_parallel",
    long_context_ok=True,
    source="arXiv:2411.15242",
)
