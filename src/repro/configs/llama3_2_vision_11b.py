"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; every 5th layer is a gated cross-attention block over image
patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision]
The ViT vision encoder is STUBBED per the assignment carve-out: input_specs
provides precomputed patch embeddings (1601 patches, dim 1280 — the
Llama-vision projector input width)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
    block_pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    frontend="vision",
    frontend_seq=1601,
    frontend_dim=1280,
    tie_embeddings=False,
    round_mode="cohort_sequential",
    long_context_ok=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
