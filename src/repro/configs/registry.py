"""Architecture + input-shape registry.

Every assigned architecture registers its exact ArchConfig here (one module
per arch, citing its source).  ``input_specs`` builds weak-type-correct
ShapeDtypeStruct stand-ins for every model input of a given (arch, shape,
step) combination — the dry-run lowers against these without allocating.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

__all__ = ["get_config", "has_arch", "list_archs", "INPUT_SHAPES", "input_specs", "step_kind", "ARCH_MODULES"]

ARCH_MODULES = {
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "whisper-small": "repro.configs.whisper_small",
    "smollm-360m": "repro.configs.smollm_360m",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "llama3-405b": "repro.configs.llama3_405b",
    "arctic-480b": "repro.configs.arctic_480b",
    "llama-3.2-vision-11b": "repro.configs.llama3_2_vision_11b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCH_MODULES:
        raise ValueError(f"unknown arch {name!r}; options: {list_archs()}")
    mod = importlib.import_module(ARCH_MODULES[name])
    return mod.CONFIG


def has_arch(name: str) -> bool:
    """Whether ``name`` is a registered zoo architecture (spec validation)."""
    return name in ARCH_MODULES


def list_archs() -> list[str]:
    return list(ARCH_MODULES)


def step_kind(cfg: ArchConfig, shape: InputShape) -> str | None:
    """Which step a (arch, shape) pair lowers — None means 'skip' (recorded
    in DESIGN.md section 4: long_500k only for sub-quadratic archs)."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return None
    return shape.kind


def _aux_embed_spec(cfg: ArchConfig, batch: int):
    if not cfg.frontend:
        return None
    fd = cfg.frontend_dim or cfg.d_model
    return jax.ShapeDtypeStruct((batch, cfg.frontend_seq, fd), jnp.float32)


def input_specs(cfg: ArchConfig, shape: InputShape, cohort: int = 1) -> dict:
    """Abstract inputs for one step.

    train:   tokens/targets (global_batch, seq) [+ frontend embeds]
    prefill: tokens (global_batch, seq) [+ frontend embeds]
    decode:  token (global_batch, 1) + caches(seq_len) + index
    """
    kind = step_kind(cfg, shape)
    if kind is None:
        raise ValueError(f"{cfg.name} skips {shape.name}")
    tok = jnp.int32
    b, s = shape.global_batch, shape.seq_len
    if kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "targets": jax.ShapeDtypeStruct((b, s), tok),
        }
        aux = _aux_embed_spec(cfg, b)
        if aux is not None:
            specs["aux_embeds"] = aux
        return specs
    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
        aux = _aux_embed_spec(cfg, b)
        if aux is not None:
            specs["aux_embeds"] = aux
        return specs
    # decode: abstract caches via eval_shape (no allocation)
    from repro.models import transformer

    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, b, s))
    return {
        "token": jax.ShapeDtypeStruct((b, 1), tok),
        "caches": caches,
        "index": jax.ShapeDtypeStruct((), tok),
    }
