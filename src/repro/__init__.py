"""Reproduction of "Enhanced Federated Optimization: Adaptive Unbiased
Client Sampling with Reduced Variance".

The declarative experiment API is re-exported lazily at the top level::

    from repro import ExperimentSpec, run

Lazy (PEP 562) so that ``import repro`` stays side-effect-free: entry points
that must configure the environment before jax initializes (notably
``python -m repro.launch.dryrun`` and its XLA_FLAGS device-count override)
import through this package without dragging jax in early.
"""
_API_EXPORTS = (
    "ExperimentSpec",
    "TaskSpec",
    "SamplerSpec",
    "FederationSpec",
    "ExecutionSpec",
    "FaultSpec",
    "CompressionSpec",
    "ServeSpec",
    "BuiltExperiment",
    "build",
    "run",
    "restore_template",
    "register_task",
    "register_dataset",
)

__all__ = list(_API_EXPORTS)


def __getattr__(name):
    if name in _API_EXPORTS:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_EXPORTS))
