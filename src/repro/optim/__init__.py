from repro.optim.fedopt import FedAdam, FedAvgServer, ServerOptimizer
from repro.optim.sgd import sgd_step, momentum_init, momentum_step

__all__ = [
    "FedAdam",
    "FedAvgServer",
    "ServerOptimizer",
    "sgd_step",
    "momentum_init",
    "momentum_step",
]
