"""Server optimizers (Reddi et al. 2020 FedOpt family).

The paper's Algorithm 1 uses x^{t+1} = x^t - eta_g d^t (FedAvgServer with
eta_g = 1).  FedAdam is provided as a framework feature (disabled in the
paper-faithful experiment configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ServerOptimizer", "FedAvgServer", "FedAdam"]


@dataclasses.dataclass(frozen=True)
class ServerOptimizer:
    lr: float = 1.0

    def init(self, params) -> Any:
        return ()

    def apply(self, params, estimate, state):
        """estimate = d^t (weighted client *updates*, a descent direction)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FedAvgServer(ServerOptimizer):
    def apply(self, params, estimate, state):
        new = jax.tree_util.tree_map(
            lambda p, d: p - self.lr * d.astype(p.dtype), params, estimate
        )
        return new, state


@dataclasses.dataclass(frozen=True)
class FedAdam(ServerOptimizer):
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-3

    def init(self, params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return (z, jax.tree_util.tree_map(jnp.zeros_like, params), jnp.zeros((), jnp.int32))

    def apply(self, params, estimate, state):
        m, v, t = state
        t = t + 1
        m = jax.tree_util.tree_map(
            lambda m_, d: self.beta1 * m_ + (1 - self.beta1) * d.astype(m_.dtype), m, estimate
        )
        v = jax.tree_util.tree_map(
            lambda v_, d: self.beta2 * v_ + (1 - self.beta2) * jnp.square(d.astype(v_.dtype)),
            v,
            estimate,
        )
        bc1 = 1 - self.beta1 ** t.astype(jnp.float32)
        bc2 = 1 - self.beta2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m_, v_: p
            - self.lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps),
            params,
            m,
            v,
        )
        return new, (m, v, t)
