"""Client-side optimizers (the paper uses vanilla SGD with constant step)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sgd_step", "momentum_init", "momentum_step"]


def sgd_step(params, grads, lr):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)


def momentum_init(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def momentum_step(params, mom, grads, lr, beta=0.9):
    mom = jax.tree_util.tree_map(lambda m, g: beta * m + g.astype(m.dtype), mom, grads)
    params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, mom)
    return params, mom
