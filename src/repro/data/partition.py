"""Federated data partitioning: power-law sizes, Dirichlet label skew.

Reproduces the heterogeneity regimes of the paper's experiments:
Section 6.1 power-law client sizes (Figure 3a), Section 6.2 FEMNIST-style
unbalanced splits (v1: 10% of clients hold 82% of data, etc.), and the
"heavy long tail" text partitions of Section 6.3.
"""
from __future__ import annotations

import numpy as np

__all__ = ["power_law_sizes", "dirichlet_label_partition", "size_share"]


def power_law_sizes(
    n_clients: int,
    total: int,
    alpha: float = 1.5,
    min_size: int = 8,
    seed: int = 0,
) -> np.ndarray:
    """Client dataset sizes following a (Zipf-like) power law, sum == total."""
    rng = np.random.default_rng(seed)
    raw = (np.arange(1, n_clients + 1, dtype=np.float64)) ** (-alpha)
    rng.shuffle(raw)
    sizes = raw / raw.sum() * (total - min_size * n_clients)
    sizes = np.floor(sizes).astype(np.int64) + min_size
    # distribute the rounding remainder
    deficit = total - sizes.sum()
    order = rng.permutation(n_clients)
    sizes[order[: int(abs(deficit))]] += int(np.sign(deficit))
    assert sizes.sum() == total and (sizes >= min_size // 2).all()
    return sizes


def size_share(sizes: np.ndarray, top_frac: float) -> float:
    """Fraction of data held by the top `top_frac` largest clients —
    the paper's unbalance statistic (e.g. FEMNIST v1: top 10% hold 82%)."""
    s = np.sort(sizes)[::-1]
    k = max(1, int(round(top_frac * len(s))))
    return float(s[:k].sum() / s.sum())


def dirichlet_label_partition(
    labels: np.ndarray,
    n_clients: int,
    beta: float = 0.5,
    seed: int = 0,
) -> list[np.ndarray]:
    """Label-skew partition: per-class proportions ~ Dirichlet(beta).

    Returns a list of index arrays, one per client.
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    client_indices: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, beta))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            client_indices[client].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in client_indices]
