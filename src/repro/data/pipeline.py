"""Federated dataset container + batching.

Clients hold ragged datasets; for TPU-friendly vmapped simulation we pad all
clients to the max size and carry a validity mask.  Batch selection draws
uniformly from each client's valid region (with replacement across steps,
matching stochastic local SGD).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FederatedDataset", "synthetic_classification", "synthetic_tokens"]


@dataclasses.dataclass
class FederatedDataset:
    """Padded per-client data: features (N, S_max, ...), labels (N, S_max)."""

    features: jax.Array
    labels: jax.Array
    sizes: jax.Array  # (N,) valid count per client

    @property
    def n_clients(self) -> int:
        return self.features.shape[0]

    @property
    def lam(self) -> jax.Array:
        """Client objective weights lambda_i proportional to dataset size
        (the FedAvg weighting of eq. 1)."""
        s = self.sizes.astype(jnp.float32)
        return s / jnp.sum(s)

    def client_batch(self, client: jax.Array, key: jax.Array, batch_size: int):
        """Uniform-with-replacement batch from one client's valid region."""
        idx = jax.random.randint(key, (batch_size,), 0, self.sizes[client])
        return self.features[client, idx], self.labels[client, idx]

    def batch_all_clients(self, key: jax.Array, batch_size: int):
        """(N, B, ...) batches for vmapped full-cohort simulation."""
        keys = jax.random.split(key, self.n_clients)

        def one(client, k):
            idx = jax.random.randint(k, (batch_size,), 0, self.sizes[client])
            return self.features[client, idx], self.labels[client, idx]

        return jax.vmap(one)(jnp.arange(self.n_clients), keys)


def synthetic_classification(
    n_clients: int = 100,
    alpha: float = 1.0,
    beta: float = 1.0,
    dim: int = 60,
    n_classes: int = 10,
    total: int = 20000,
    power: float = 1.5,
    seed: int = 0,
) -> FederatedDataset:
    """Synthetic(alpha, beta) of Li et al. 2020 — the paper's Section 6.1 task.

    Per client i: u_i ~ N(0, alpha); W_i ~ N(u_i, 1) in R^{C x d},
    b_i ~ N(u_i, 1); v_i ~ N(B_i, 1) with B_i ~ N(0, beta);
    x ~ N(v_i, diag(j^-1.2)); y = argmax(W_i x + b_i).  Sizes ~ power law.
    """
    from repro.data.partition import power_law_sizes

    rng = np.random.default_rng(seed)
    sizes = power_law_sizes(n_clients, total, alpha=power, seed=seed)
    s_max = int(sizes.max())
    feats = np.zeros((n_clients, s_max, dim), np.float32)
    labels = np.zeros((n_clients, s_max), np.int32)
    cov_diag = np.arange(1, dim + 1, dtype=np.float64) ** (-1.2)
    for i in range(n_clients):
        u = rng.normal(0, np.sqrt(alpha))
        b_mean = rng.normal(0, np.sqrt(beta))
        w = rng.normal(u, 1.0, size=(n_classes, dim))
        b = rng.normal(u, 1.0, size=(n_classes,))
        v = rng.normal(b_mean, 1.0, size=(dim,))
        x = rng.normal(v, np.sqrt(cov_diag), size=(int(sizes[i]), dim))
        logits = x @ w.T + b
        y = logits.argmax(axis=1)
        feats[i, : sizes[i]] = x.astype(np.float32)
        labels[i, : sizes[i]] = y.astype(np.int32)
        # pad region repeats the first sample (masked out by `sizes`)
        feats[i, sizes[i] :] = feats[i, 0]
        labels[i, sizes[i] :] = labels[i, 0]
    return FederatedDataset(
        features=jnp.asarray(feats), labels=jnp.asarray(labels), sizes=jnp.asarray(sizes)
    )


def synthetic_tokens(
    n_clients: int,
    seq_len: int,
    vocab: int,
    total_seqs: int,
    power: float = 1.5,
    n_styles: int = 8,
    seed: int = 0,
) -> FederatedDataset:
    """Heterogeneous federated token streams (Section 6.3 scaled down).

    Each client draws from one of ``n_styles`` Markov-ish token generators so
    client gradients genuinely differ (heterogeneity drives the sampler).
    """
    rng = np.random.default_rng(seed)
    from repro.data.partition import power_law_sizes

    sizes = power_law_sizes(n_clients, total_seqs, alpha=power, seed=seed)
    s_max = int(sizes.max())
    toks = np.zeros((n_clients, s_max, seq_len), np.int32)
    # style = a biased unigram distribution + shift pattern
    styles = rng.dirichlet(np.full(vocab, 0.1), size=n_styles)
    for i in range(n_clients):
        st = styles[i % n_styles]
        t = rng.choice(vocab, p=st, size=(int(sizes[i]), seq_len))
        # inject determinism: next token correlated with previous (shift+1 mod vocab)
        t[:, 1::2] = (t[:, 0::2][:, : t[:, 1::2].shape[1]] + 1) % vocab
        toks[i, : sizes[i]] = t
        toks[i, sizes[i] :] = toks[i, 0]
    labels = np.roll(toks, -1, axis=-1)
    return FederatedDataset(
        features=jnp.asarray(toks), labels=jnp.asarray(labels), sizes=jnp.asarray(sizes)
    )
