from repro.data.partition import dirichlet_label_partition, power_law_sizes, size_share
from repro.data.pipeline import FederatedDataset, synthetic_classification, synthetic_tokens

__all__ = [
    "dirichlet_label_partition",
    "power_law_sizes",
    "size_share",
    "FederatedDataset",
    "synthetic_classification",
    "synthetic_tokens",
]
